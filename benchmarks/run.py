"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME] \
        [--json BENCH_engine_step.json]

Prints ``name,value,derived`` CSV rows; ``--json PATH`` additionally
writes every row (plus backend/version metadata) machine-readably so each
perf PR leaves a comparable trajectory point.  --full runs at the paper's
139,255-neuron scale (slower; cached after first run).
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "bench_connectome_stats",   # Figs 2-3
    "bench_compression",        # Fig 7
    "bench_partition",          # Figs 8-10, chip counts
    "bench_parity",             # Figs 6/12/13/14/15
    "bench_activity_scaling",   # Table 1, Figs 16-17, engine_step.* rows
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper scale (139k neurons)")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write all rows + metadata as JSON to PATH")
    args = ap.parse_args()

    import importlib

    from .common import write_json

    print("name,value,derived")
    t0 = time.time()
    results: dict[str, list] = {}
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t = time.time()
        try:
            results[name] = mod.run(full=args.full) or []
        except Exception as e:  # noqa: BLE001
            print(f"{name}.ERROR,{type(e).__name__},{e}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.time()-t:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    if args.json:
        write_json(args.json, results, full=args.full)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
