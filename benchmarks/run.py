"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke] [--only NAME] \
        [--json BENCH_engine_step.json]

Prints ``name,value,derived`` CSV rows; ``--json PATH`` additionally
writes every row (plus backend/host metadata) machine-readably so each
perf PR leaves a comparable trajectory point.  --full runs at the paper's
139,255-neuron scale (slower; cached after first run); --smoke runs
supporting modules at CI-tiny scale (a harness-breakage canary, not a
measurement).  A module that raises is recorded as an explicit
``<module>.error`` row (and fails the exit code) instead of aborting the
remaining modules.
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time
import traceback

MODULES = [
    "bench_connectome_stats",   # Figs 2-3
    "bench_compression",        # Fig 7
    "bench_partition",          # Figs 8-10, chip counts
    "bench_parity",             # Figs 6/12/13/14/15
    "bench_activity_scaling",   # Table 1, Figs 16-17, engine_step.* rows
    "bench_serving",            # serving-layer throughput + latency
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper scale (139k neurons)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-tiny scale for modules that support it "
                         "(harness canary, not a measurement)")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="", metavar="PATH",
                    help="write all rows + metadata as JSON to PATH")
    args = ap.parse_args()

    import importlib

    from .common import row, write_json

    print("name,value,derived")
    t0 = time.time()
    results: dict[str, list] = {}
    failed = []
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        t = time.time()
        try:
            mod = importlib.import_module(f"benchmarks.{name}")
            kw = {"full": args.full}
            if "smoke" in inspect.signature(mod.run).parameters:
                kw["smoke"] = args.smoke
            results[name] = mod.run(**kw) or []
        except Exception as e:  # noqa: BLE001 — surfaced as an .error row
            traceback.print_exc(file=sys.stderr)
            results[name] = [row(f"{name}.error", type(e).__name__, str(e))]
            failed.append(name)
        print(f"# {name} done in {time.time()-t:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)
    if args.json:
        write_json(args.json, results, full=args.full, smoke=args.smoke)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        sys.exit(f"benchmark modules failed: {', '.join(failed)}")


if __name__ == "__main__":
    main()
