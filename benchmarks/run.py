"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only NAME]

Prints ``name,value,derived`` CSV rows.  --full runs at the paper's
139,255-neuron scale (slower; cached after first run).
"""

from __future__ import annotations

import argparse
import sys
import time

MODULES = [
    "bench_connectome_stats",   # Figs 2-3
    "bench_compression",        # Fig 7
    "bench_partition",          # Figs 8-10, chip counts
    "bench_parity",             # Figs 6/12/13/14/15
    "bench_activity_scaling",   # Table 1, Figs 16-17
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper scale (139k neurons)")
    ap.add_argument("--only", default="")
    args = ap.parse_args()

    import importlib
    print("name,value,derived")
    t0 = time.time()
    for name in MODULES:
        if args.only and args.only not in name:
            continue
        mod = importlib.import_module(f"benchmarks.{name}")
        t = time.time()
        try:
            mod.run(full=args.full)
        except Exception as e:  # noqa: BLE001
            print(f"{name}.ERROR,{type(e).__name__},{e}", file=sys.stderr)
            raise
        print(f"# {name} done in {time.time()-t:.1f}s", file=sys.stderr)
    print(f"# total {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
