"""Shared benchmark utilities."""

import json
import os
import platform
import re
import socket
import time

import numpy as np

# benchmark-scale synthetic connectome (full-scale 139k runs via
# --full; the shapes of all paper claims are scale-free)
BENCH_N = 20_000
BENCH_SYN = 600_000
FULL_N = 139_255
FULL_SYN = 15_000_000


def timeit(fn, *args, warmup=1, iters=3):
    """Median wall time of ``fn(*args)`` with the result blocked on.

    JAX dispatch is async: without ``block_until_ready`` inside the timed
    region a returned-but-still-executing computation under-reports, and
    an unblocked warmup lets the first timed iteration absorb the tail of
    the warmup's execution.  Non-array results (host-side fns) pass
    through untouched."""
    import jax

    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name, value, derived=""):
    print(f"{name},{value},{derived}")
    return (name, value, derived)


def host_identity() -> dict:
    """Hostname + CPU model + physical core count.  Stamped into every
    BENCH_*.json so trajectory points from different machines can't be
    silently compared (steps/sec is only meaningful same-host)."""
    model, physical = "", None
    try:
        with open("/proc/cpuinfo") as f:
            info = f.read()
        m = re.search(r"^model name\s*:\s*(.+)$", info, re.M)
        model = m.group(1).strip() if m else ""
        cores = {(p.group(1), c.group(1))
                 for blk in info.split("\n\n")
                 if (p := re.search(r"^physical id\s*:\s*(\d+)$", blk, re.M))
                 and (c := re.search(r"^core id\s*:\s*(\d+)$", blk, re.M))}
        physical = len(cores) or None
    except OSError:
        pass
    return {
        "hostname": socket.gethostname(),
        "cpu_model": model or platform.processor(),
        "physical_cores": physical or os.cpu_count(),
        "logical_cpus": os.cpu_count(),
    }


def write_json(path: str, results: dict, full: bool,
               smoke: bool = False) -> None:
    """Persist benchmark rows machine-readably so every perf PR leaves a
    comparable trajectory point (BENCH_*.json convention).  ``smoke`` is
    stamped so CI-tiny canary runs can never be mistaken for (or compared
    against) real trajectory points."""
    import jax

    payload = {
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "platform": platform.platform(),
            "host": host_identity(),
            "full": full,
            "smoke": smoke,
        },
        "benchmarks": {
            name: [{"name": n, "value": v, "derived": d}
                   for (n, v, d) in rows]
            for name, rows in results.items()
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
