"""Shared benchmark utilities."""

import json
import platform
import time

import numpy as np

# benchmark-scale synthetic connectome (full-scale 139k runs via
# --full; the shapes of all paper claims are scale-free)
BENCH_N = 20_000
BENCH_SYN = 600_000
FULL_N = 139_255
FULL_SYN = 15_000_000


def timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def row(name, value, derived=""):
    print(f"{name},{value},{derived}")
    return (name, value, derived)


def write_json(path: str, results: dict, full: bool) -> None:
    """Persist benchmark rows machine-readably so every perf PR leaves a
    comparable trajectory point (BENCH_*.json convention)."""
    import jax

    payload = {
        "meta": {
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "platform": platform.platform(),
            "full": full,
        },
        "benchmarks": {
            name: [{"name": n, "value": v, "derived": d}
                   for (n, v, d) in rows]
            for name, rows in results.items()
        },
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
