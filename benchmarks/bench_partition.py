"""Paper Figs 8-10 + §3.2.4: greedy capacity partitioning vs even split,
per-core neuron/fan/memory distributions, and the Loihi-2 chip estimate
(paper: SAR -> 12 chips / 1440 cores, SSD -> 20 chips, 120 cores/chip)."""

from __future__ import annotations

import numpy as np

from repro.core import (CoreBudget, caps_from_budget, even_partition,
                        greedy_partition, partition_report,
                        synthetic_flywire_cached)
from .common import BENCH_N, BENCH_SYN, row


def run(full: bool = False):
    n, syn = (139_255, 15_000_000) if full else (BENCH_N, BENCH_SYN)
    c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn)
    budget = CoreBudget.loihi2()
    rows = []
    for scheme in ("sar", "ssd"):
        caps = caps_from_budget(budget, scheme)
        p = greedy_partition(c, caps, scheme=scheme)
        rep = partition_report(c, p, budget)
        chips = int(np.ceil(p.n_parts / 120))
        rows.append(row(f"fig8.{scheme}.n_cores", p.n_parts,
                        "paper: SAR 1440, SSD 2400 at full scale"))
        rows.append(row(f"fig8.{scheme}.n_chips", chips,
                        "paper: SAR 12, SSD 20"))
        rows.append(row(f"fig8.{scheme}.neurons_per_core_p5_p50_p95",
                        f"{int(np.percentile(rep['neurons'],5))}/"
                        f"{int(np.percentile(rep['neurons'],50))}/"
                        f"{int(np.percentile(rep['neurons'],95))}",
                        "uneven by design (Fig 8)"))
        rows.append(row(f"fig10.{scheme}.mem_util_mean",
                        f"{rep['mem_util'].mean():.3f}",
                        "paper: SAR 56.4%, SSD 80.0%"))
        rows.append(row(f"fig10.{scheme}.mem_util_max",
                        f"{rep['mem_util'].max():.3f}", "must be <= 1"))
    # even-split baseline (what the paper argues against): same number of
    # cores, but the outlier cores overshoot the balanced max utilization
    caps = caps_from_budget(budget, "sar")
    g = greedy_partition(c, caps, scheme="sar")
    e = even_partition(c, g.n_parts)
    rep_g = partition_report(c, g, budget)
    rep_e = partition_report(c, e, budget)
    rows.append(row("fig8.even_split.max_util_ratio",
                    f"{rep_e['mem_util'].max()/rep_g['mem_util'].max():.2f}",
                    "even-split hottest core vs greedy hottest core"))
    rows.append(row("fig8.even_split.frac_cores_over_budget",
                    f"{float((rep_e['mem_util'] > 1.0).mean()):.3f}",
                    "cores exceeding the 128KB budget under even split"))
    return rows
