"""Serving-layer throughput under a fault-injected workload.

    PYTHONPATH=src python -m benchmarks.bench_serving --json BENCH_serving.json

Drives a mixed multi-tenant workload — two scenario tiers, distinct
seeds, one crash-injected request (the ``faulty`` exchange wrapper's
host hook) and one poisoned (NaN-stimulus) request — through
:class:`repro.serving.SimServer` and records what the serving PR is
accountable for: concurrent scenario-trials/sec (completed requests per
wall second, each request being one full T-step trial) and the
completed-request latency p50/p99, plus the retry/shed/quarantine
accounting.  Also runs as a module of ``benchmarks.run`` (rows land in
the shared ``--json`` payload under ``bench_serving``).
"""

from __future__ import annotations

import time

from .common import row

# (n, synapses, t_steps, requests, max_batch)
SMOKE_SCALE = (400, 8_000, 50, 8, 4)
BENCH_SCALE = (2_000, 60_000, 200, 16, 8)
FULL_SCALE = (20_000, 600_000, 500, 24, 8)


def _workload(t_steps: int, requests: int):
    from repro.core.exchange import FaultSpec, configure_faulty
    from repro.exp import ProbeSpec
    from repro.serving import SimRequest

    reqs = [SimRequest(scenario="sugar_feeding" if i % 2 else "step_response",
                       t_steps=t_steps, seed=i,
                       probes=ProbeSpec(pop_rate=True))
            for i in range(requests)]
    # one transient crash (retried with backoff) + one poison (quarantined
    # after two health failures): the measured number is throughput under
    # supervision, not a fair-weather spikes/sec
    spec = FaultSpec(partition=0, fail_at=(t_steps // 2,))
    reqs[0].fault_hook = configure_faulty("event", spec).host_supervise
    reqs.append(SimRequest(scenario="step_response", t_steps=t_steps,
                           seed=len(reqs), params={"amp": float("nan")}))
    return reqs


def run(full: bool = False, smoke: bool = False):
    from repro.core import SimConfig, synthetic_flywire_cached
    from repro.core.health import BackoffPolicy, HealthConfig
    from repro.serving import SimServeConfig, SimServer

    n, syn, t_steps, requests, max_batch = (
        FULL_SCALE if full else SMOKE_SCALE if smoke else BENCH_SCALE)
    c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn)
    cfg = SimConfig(engine="csr", health=HealthConfig())
    serve = SimServeConfig(
        max_batch=max_batch, max_queue=2 * requests,
        chunk_steps=max(t_steps // 4, 1),
        backoff=BackoffPolicy(base_s=0.01, cap_s=0.5, jitter=0.0))
    server = SimServer(c, cfg, serve)
    reqs = _workload(t_steps, requests)

    t0 = time.perf_counter()
    done = server.run(reqs)
    wall = time.perf_counter() - t0

    s = server.stats()
    assert all(r.terminal for r in done), "non-terminal request in bench"
    rows = [
        row("serving.requests", s["submitted"],
            f"n={n} t_steps={t_steps} max_batch={max_batch}"),
        row("serving.completed", s["completed"],
            f"rejected={s['rejected']} quarantined={s['quarantined']}"),
        row("serving.trials_per_s", round(s["completed"] / wall, 4),
            f"wall={wall:.2f}s concurrent fault-injected workload"),
        row("serving.steps_per_s",
            round(s["completed"] * t_steps / wall, 1),
            "completed trial-steps per wall second"),
        row("serving.latency_p50_s", round(s["latency_p50_s"] or 0.0, 4),
            "completed-request submit->finish"),
        row("serving.latency_p99_s", round(s["latency_p99_s"] or 0.0, 4),
            "completed-request submit->finish"),
        row("serving.retries", s["retries"],
            f"escalations={s['escalations']}"),
        row("serving.shed", s["shed"], f"deadline={s['deadline_expired']}"),
        row("serving.quarantined", s["quarantined"], "poison isolated"),
        row("serving.batches", s["batches"],
            f"chunks={s['chunks']} (signature-packed vmap scans)"),
    ]
    return rows


def main() -> None:
    import argparse

    from .common import write_json

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default="", metavar="PATH")
    args = ap.parse_args()
    print("name,value,derived")
    rows = run(full=args.full, smoke=args.smoke)
    if args.json:
        write_json(args.json, {"bench_serving": rows}, full=args.full,
                   smoke=args.smoke)
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
