"""Paper Table 1 + Figs 16-17: runtime vs spiking activity.

The paper's claim: conventional flat delivery (Brian2-like, cost ~ nnz)
is insensitive to activity, while the event-driven path scales with it —
the advantage grows as activity sparsifies.  We reproduce the *relative*
scaling on CPU with the JAX engines (dense/csr = conventional;
event = Loihi-like; binned = SAR-compressed; blocked = tile-gated Pallas,
compiled path on TPU only) across the paper's background-rate sweep, plus
the sugar experiment.  ``engine_step.*`` rows record steps/sec per engine
at each sweep point — the perf trajectory every optimisation PR is
measured against (``--json BENCH_engine_step.json``).  The spike-probe
slowdown (paper §3.2.5) is reproduced via probe=True (per-step host
sync)."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import (SimConfig, auto_capacity, simulate,
                        synthetic_flywire_cached)
from repro.core.engine import build_synapses
from .common import row, timeit

# large enough that synaptic delivery (not per-op dispatch overhead)
# dominates a CPU step — the regime where Table 1's scaling is measurable
N, SYN, T = 60_000, 6_000_000, 100
RATES = [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0]


def _run_sim(c, cfg, syn, sugar=None, probe=False):
    res = simulate(c, cfg, T, sugar, seed=0, syn=syn)
    if probe:
        # per-step host sync is emulated by fetching the raster per chunk
        np.asarray(res.counts)
    jax.block_until_ready(res.counts)
    return res


def engines_for(c, rate_hz):
    cap, budget = auto_capacity(c, max(rate_hz, 0.5))
    engines = {
        "csr(conventional)": SimConfig(engine="csr"),
        "event(loihi-like)": SimConfig(engine="event",
                                       spike_capacity=cap,
                                       syn_budget=budget),
        "binned(SAR)": SimConfig(engine="binned", quantize_bits=9),
    }
    if jax.default_backend() == "tpu":
        # interpret-mode fallback is orders of magnitude off at bench
        # scale; the compiled tile-gated path only exists on TPU.
        engines["blocked(tile-gated)"] = SimConfig(engine="blocked",
                                                   quantize_bits=9)
    return engines


def run(full: bool = False):
    c = synthetic_flywire_cached(n=N, seed=0, target_synapses=SYN)
    sugar = np.arange(20)
    rows = []
    if jax.default_backend() != "tpu":
        rows.append(row("engine_step.blocked.skipped", "cpu-backend",
                        "compiled tile-gated path is TPU-only; interpret "
                        "fallback excluded from bench-scale timing"))

    # --- sugar experiment column (activity ~0.1 Hz effective) ---
    for name, cfg in engines_for(c, 0.5).items():
        syn = build_synapses(c, cfg)
        res = _run_sim(c, cfg, syn, sugar=sugar)
        t = timeit(lambda: _run_sim(c, cfg, syn, sugar=sugar))
        rows.append(row(f"table1.sugar.{name}", f"{t*1e3:.1f}ms",
                        f"{T} steps of dt=0.1ms dropped="
                        f"{int(res.dropped)}"))

    # --- background-rate sweep; engine_step.* is the perf trajectory ---
    times = {}
    for rate in RATES:
        for name, base in engines_for(c, rate).items():
            cfg = dataclasses.replace(base, background_rate_hz=rate,
                                      poisson_rate_hz=0.0)
            syn = build_synapses(c, cfg)
            res = _run_sim(c, cfg, syn)
            t = timeit(lambda: _run_sim(c, cfg, syn), iters=2)
            times[(name, rate)] = t
            rows.append(row(f"table1.{rate}hz.{name}", f"{t*1e3:.1f}ms",
                            f"dropped={int(res.dropped)}"))
            engine = base.engine
            rows.append(row(f"engine_step.{engine}.{rate}hz",
                            f"{T/t:.1f}",
                            f"steps/sec ({t/T*1e3:.3f} ms/step, n={c.n})"))

    # --- the paper's headline ratios ---
    for rate in (0.5, 40.0):
        ratio = times[("csr(conventional)", rate)] / \
            times[("event(loihi-like)", rate)]
        rows.append(row(f"fig17.speedup_event_vs_csr.{rate}hz",
                        f"{ratio:.2f}x",
                        "paper: advantage grows as activity sparsifies"))
    flat = times[("csr(conventional)", 40.0)] / \
        times[("csr(conventional)", 0.5)]
    scal = times[("event(loihi-like)", 40.0)] / \
        times[("event(loihi-like)", 0.5)]
    rows.append(row("fig16.csr_40hz_over_0.5hz", f"{flat:.2f}x",
                    "conventional: ~flat in activity (paper: 1.4x)"))
    rows.append(row("fig16.event_40hz_over_0.5hz", f"{scal:.2f}x",
                    "event-driven: cost tracks activity (paper: ~50x)"))

    # --- spike-probe slowdown (paper §3.2.5) ---
    cfg = SimConfig(engine="event", collect_raster=True)
    syn = build_synapses(c, cfg)
    t_probe = timeit(lambda: np.asarray(
        simulate(c, cfg, T, sugar, seed=0, syn=syn).raster), iters=2)
    cfg2 = SimConfig(engine="event")
    syn2 = build_synapses(c, cfg2)
    t_free = timeit(lambda: _run_sim(c, cfg2, syn2, sugar=sugar), iters=2)
    rows.append(row("probe.slowdown", f"{t_probe/t_free:.2f}x",
                    "raster collection vs counters-only (paper: probes "
                    "significantly slow execution)"))
    return rows
