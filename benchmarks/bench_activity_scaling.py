"""Paper Table 1 + Figs 16-17: runtime vs spiking activity.

The paper's claim: conventional flat delivery (Brian2-like, cost ~ nnz)
is insensitive to activity, while the event-driven path scales with it —
the advantage grows as activity sparsifies.  We reproduce the *relative*
scaling on CPU with the JAX engines (dense/csr = conventional;
event = Loihi-like; binned = SAR-compressed) across the paper's
background-rate sweep, plus the sugar experiment.  The spike-probe
slowdown (paper §3.2.5) is reproduced via probe=True (per-step host
sync)."""

from __future__ import annotations

import jax
import numpy as np

from repro.core import SimConfig, simulate, synthetic_flywire_cached
from repro.core.engine import build_synapses
from .common import row, timeit

# large enough that synaptic delivery (not per-op dispatch overhead)
# dominates a CPU step — the regime where Table 1's scaling is measurable
N, SYN, T = 60_000, 6_000_000, 100
RATES = [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0]


def _run_sim(c, cfg, syn, sugar=None, probe=False):
    res = simulate(c, cfg, T, sugar, seed=0, syn=syn)
    if probe:
        # per-step host sync is emulated by fetching the raster per chunk
        np.asarray(res.counts)
    jax.block_until_ready(res.counts)
    return res


def auto_capacity(c, rate_hz, dt_ms=0.1, margin=4.0):
    """Provision the event engine for the expected activity level — the
    static-shape analogue of Loihi's 'work ~ actual spike count'.  The
    engine still *counts* drops, so under-provisioning is observable."""
    exp_spikes = max(1.0, c.n * rate_hz * dt_ms * 1e-3)
    cap = int(max(64, min(c.n, margin * exp_spikes)))
    mean_fo = max(1.0, c.nnz / c.n)
    budget = int(max(4096, cap * mean_fo * margin))
    return cap, budget


def run(full: bool = False):
    c = synthetic_flywire_cached(n=N, seed=0, target_synapses=SYN)
    sugar = np.arange(20)
    rows = []

    def engines_for(rate_hz):
        cap, budget = auto_capacity(c, max(rate_hz, 0.5))
        return {
            "csr(conventional)": SimConfig(engine="csr"),
            "event(loihi-like)": SimConfig(engine="event",
                                           spike_capacity=cap,
                                           syn_budget=budget),
            "binned(SAR)": SimConfig(engine="binned", quantize_bits=9),
        }

    # --- sugar experiment column (activity ~0.1 Hz effective) ---
    for name, cfg in engines_for(0.5).items():
        syn = build_synapses(c, cfg)
        res = _run_sim(c, cfg, syn, sugar=sugar)
        t = timeit(lambda: _run_sim(c, cfg, syn, sugar=sugar))
        rows.append(row(f"table1.sugar.{name}", f"{t*1e3:.1f}ms",
                        f"{T} steps of dt=0.1ms dropped="
                        f"{int(res.dropped)}"))

    # --- background-rate sweep ---
    times = {}
    for rate in RATES:
        for name, base in engines_for(rate).items():
            cfg = SimConfig(**{**base.__dict__,
                               "background_rate_hz": rate,
                               "poisson_rate_hz": 0.0})
            syn = build_synapses(c, cfg)
            res = _run_sim(c, cfg, syn)
            t = timeit(lambda: _run_sim(c, cfg, syn), iters=2)
            times[(name, rate)] = t
            rows.append(row(f"table1.{rate}hz.{name}", f"{t*1e3:.1f}ms",
                            f"dropped={int(res.dropped)}"))

    # --- the paper's headline ratios ---
    for rate in (0.5, 40.0):
        ratio = times[("csr(conventional)", rate)] / \
            times[("event(loihi-like)", rate)]
        rows.append(row(f"fig17.speedup_event_vs_csr.{rate}hz",
                        f"{ratio:.2f}x",
                        "paper: advantage grows as activity sparsifies"))
    flat = times[("csr(conventional)", 40.0)] / \
        times[("csr(conventional)", 0.5)]
    scal = times[("event(loihi-like)", 40.0)] / \
        times[("event(loihi-like)", 0.5)]
    rows.append(row("fig16.csr_40hz_over_0.5hz", f"{flat:.2f}x",
                    "conventional: ~flat in activity (paper: 1.4x)"))
    rows.append(row("fig16.event_40hz_over_0.5hz", f"{scal:.2f}x",
                    "event-driven: cost tracks activity (paper: ~50x)"))

    # --- spike-probe slowdown (paper §3.2.5) ---
    cfg = SimConfig(engine="event", collect_raster=True)
    syn = build_synapses(c, cfg)
    t_probe = timeit(lambda: np.asarray(
        simulate(c, cfg, T, sugar, seed=0, syn=syn).raster), iters=2)
    cfg2 = SimConfig(engine="event")
    syn2 = build_synapses(c, cfg2)
    t_free = timeit(lambda: _run_sim(c, cfg2, syn2, sugar=sugar), iters=2)
    rows.append(row("probe.slowdown", f"{t_probe/t_free:.2f}x",
                    "raster collection vs counters-only (paper: probes "
                    "significantly slow execution)"))
    return rows
