"""Paper Table 1 + Figs 16-17: runtime vs spiking activity.

The paper's claim: conventional flat delivery (Brian2-like, cost ~ nnz)
is insensitive to activity, while the event-driven path scales with it —
the advantage grows as activity sparsifies.  We reproduce the *relative*
scaling on CPU with the JAX engines (dense/csr = conventional;
event = Loihi-like; binned = SAR-compressed; blocked = tile-gated Pallas,
compiled path on TPU only).

All stimulation flows through the scenario registry (repro.exp): the
background-rate sweep is the ``activity_sweep`` scenario with
``background_hz`` as its parameter, and the ``engine_step.*`` steps/sec
rows — the perf trajectory every optimisation PR is measured against
(``--json BENCH_engine_step.json``) — now also cover stimulus diversity
via per-scenario rows (``engine_step.<engine>.scenario.<name>``) and the
fixed-rate n-scaling sweep (``engine_step.event.nscale.<n>``), which
demonstrates the hierarchical-compaction claim: event-engine ms/step
grows sublinearly in n at fixed sparse activity (cost O(n/B + K·B +
S_cap), not O(n)).  The spike-probe slowdown (paper §3.2.5) is reproduced
via ``ProbeSpec(raster=True)`` (per-step record stacking + host fetch).
The distributed exchange schemes (``engine_step.dist.<scheme>.P4``,
vmap-emulated on one device) extend the trajectory across the partition
cut; the sharded ``blocked`` row additionally records the tile-gating
metric (tiles skipped/step ∝ sparsity).  The fused delivery->LIF rows
(``engine_step.blocked_fused.*``, interpret mode at small n like every
blocked-kernel CPU row) pin the one-kernel step composition — float32
and the Q19.12 int32 path — so a regression in the fused fast path shows
up in the trajectory, not just in the bit-identity tests.  The chunked
supervision rows (``engine_step.event.chunked.{K}`` and the
``.checkpointed`` variant) price the resilience layer's chunk
boundaries (docs/resilience.md): same bit-identical run, one compiled
K-step program reused ceil(T/K) times, with and without an atomic npz
checkpoint per boundary.  The ``engine_step.event.telemetry_overhead``
row prices the repro.obs streamed-event layer (docs/observability.md)
against the same chunked run — the < 2%-of-step-time contract.

``smoke=True`` shrinks every scale knob to CI size: a harness-breakage
canary (imports, retracing, capacity plumbing), not a measurement.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import (SimConfig, auto_capacity, simulate,
                        synthetic_flywire_cached)
from repro.core.engine import build_synapses
from repro.exp import ProbeSpec, build_scenario
from .common import row, timeit

# large enough that synaptic delivery (not per-op dispatch overhead)
# dominates a CPU step — the regime where Table 1's scaling is measurable
N, SYN, T = 60_000, 6_000_000, 100
RATES = [0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 40.0]
# fixed-rate n-scaling sweep (event engine, sparsest rate): n grows at
# constant mean fan-out, activity rate — and therefore the event path's
# per-step budgets — stay fixed
NSCALE = [15_000, 30_000, 60_000, 120_000]
NSCALE_RATE = 0.5
MEAN_FANOUT = 100
# distributed exchange-scheme rows (vmap-emulated, one host device):
# bitmap/event run at the bench n so dist.P4 vs monolithic overhead is
# readable; the blocked scheme times its interpret-mode fallback, so it
# runs at a small n and its row is about the tiles-skipped gating metric,
# not speed (compiled tile path is TPU-only, like the monolithic row)
DIST_P = 4
DIST_RATE = 0.5
DIST_BLOCKED_N = 2_000
DIST_BLOCKED_RATES = (0.5, 40.0)
# fused delivery->LIF rows: like the blocked rows, the compiled tile path
# is TPU-only, so CPU times the interpret fallback at a small n and the
# row's point is the fused-vs-unfused step composition (no HBM round-trip
# between delivery and integration), not absolute speed
FUSED_N = 2_000
FUSED_RATES = (0.5, 40.0)
# stimulus-diversity trajectory points (scenario name -> params);
# sugar_feeding rows are reused from the table1.sugar block, not re-timed
SCENARIOS = {
    "background_storm": {"background_hz": 40.0},
    "silent_baseline": {},
}


def _run_sim(c, cfg, syn, stim, t_steps, probes=None):
    res = simulate(c, cfg, t_steps, seed=0, syn=syn, stimulus=stim,
                   probes=probes)
    jax.block_until_ready(res.counts)
    return res


def engines_for(c, rate_hz):
    caps = auto_capacity(c, max(rate_hz, 0.5))
    engines = {
        "csr(conventional)": SimConfig(engine="csr"),
        "event(loihi-like)": SimConfig(engine="event",
                                       **caps.as_config_kwargs()),
        "binned(SAR)": SimConfig(engine="binned", quantize_bits=9),
    }
    if jax.default_backend() == "tpu":
        # interpret-mode fallback is orders of magnitude off at bench
        # scale; the compiled tile-gated path only exists on TPU.
        engines["blocked(tile-gated)"] = SimConfig(engine="blocked",
                                                   quantize_bits=9)
    return engines


def run(full: bool = False, smoke: bool = False):
    n, syn_n, t_steps = (2_000, 60_000, 20) if smoke else (N, SYN, T)
    rates = [0.5, 40.0] if smoke else RATES
    nscale = [1_000, 2_000] if smoke else NSCALE

    c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn_n)
    rows = []
    if jax.default_backend() != "tpu":
        rows.append(row("engine_step.blocked.skipped", "cpu-backend",
                        "compiled tile-gated path is TPU-only; interpret "
                        "fallback excluded from bench-scale timing"))

    # --- sugar experiment column (activity ~0.1 Hz effective); doubles as
    #     the sugar_feeding stimulus-diversity trajectory point ---
    for name, cfg in engines_for(c, 0.5).items():
        stim = build_scenario("sugar_feeding", c, cfg)
        syn = build_synapses(c, cfg)
        res = _run_sim(c, cfg, syn, stim, t_steps)
        t = timeit(lambda: _run_sim(c, cfg, syn, stim, t_steps))
        rows.append(row(f"table1.sugar.{name}", f"{t*1e3:.1f}ms",
                        f"{t_steps} steps of dt=0.1ms dropped="
                        f"{int(res.dropped)}"))
        rows.append(row(f"engine_step.{cfg.engine}.scenario.sugar_feeding",
                        f"{t_steps/t:.1f}",
                        f"steps/sec ({t/t_steps*1e3:.3f} ms/step, n={c.n}, "
                        f"dropped={int(res.dropped)})"))

    # --- background-rate sweep through the activity_sweep scenario;
    #     engine_step.<engine>.<rate>hz is the perf trajectory ---
    times = {}
    for rate in rates:
        for name, base in engines_for(c, rate).items():
            cfg = dataclasses.replace(base, poisson_rate_hz=0.0)
            stim = build_scenario("activity_sweep", c, cfg,
                                  background_hz=rate)
            syn = build_synapses(c, cfg)
            res = _run_sim(c, cfg, syn, stim, t_steps)
            t = timeit(lambda: _run_sim(c, cfg, syn, stim, t_steps), iters=2)
            times[(name, rate)] = t
            rows.append(row(f"table1.{rate}hz.{name}", f"{t*1e3:.1f}ms",
                            f"dropped={int(res.dropped)} "
                            f"scenario=activity_sweep"))
            engine = base.engine
            rows.append(row(f"engine_step.{engine}.{rate}hz",
                            f"{t_steps/t:.1f}",
                            f"steps/sec ({t/t_steps*1e3:.3f} ms/step, "
                            f"n={c.n}, scenario=activity_sweep)"))

    # --- fixed-rate n-scaling sweep: the sublinear sparse path.  At a
    #     fixed sparse rate the hierarchical compaction's budgets stop
    #     growing with n, so event ms/step must grow far slower than n ---
    ms_by_n = {}
    for n_i in nscale:
        ci = synthetic_flywire_cached(n=n_i, seed=0,
                                      target_synapses=MEAN_FANOUT * n_i)
        caps = auto_capacity(ci, NSCALE_RATE)
        cfg = SimConfig(engine="event", poisson_rate_hz=0.0,
                        **caps.as_config_kwargs())
        stim = build_scenario("activity_sweep", ci, cfg,
                              background_hz=NSCALE_RATE)
        syn = build_synapses(ci, cfg)
        res = _run_sim(ci, cfg, syn, stim, t_steps)
        t = timeit(lambda: _run_sim(ci, cfg, syn, stim, t_steps), iters=2)
        ms_by_n[n_i] = t / t_steps * 1e3
        rows.append(row(f"engine_step.event.nscale.{n_i}",
                        f"{t_steps/t:.1f}",
                        f"steps/sec ({t/t_steps*1e3:.3f} ms/step, n={n_i}, "
                        f"rate={NSCALE_RATE}hz, K={caps.spike_capacity}, "
                        f"S_cap={caps.syn_budget}, "
                        f"dropped={int(res.dropped)})"))
    n0, n1 = nscale[0], nscale[-1]
    rows.append(row("nscale.event.ms_growth",
                    f"{ms_by_n[n1]/ms_by_n[n0]:.2f}x",
                    f"event ms/step growth over {n1/n0:.0f}x n at "
                    f"{NSCALE_RATE}hz (sublinear: << n ratio)"))

    # --- chunked supervision overhead (repro.core.health): the same
    #     event-engine run as ceil(T/K) reuses of one compiled K-step
    #     program with the carry threaded host-side.  The result is
    #     bit-identical (pinned in tests/test_health.py); these rows pin
    #     what the supervision points COST, monolithic scan = baseline ---
    chunk_ks = (8, 4) if smoke else (64, 16)
    caps = auto_capacity(c, DIST_RATE)
    cfgc = SimConfig(engine="event", poisson_rate_hz=0.0,
                     **caps.as_config_kwargs())
    stimc = build_scenario("activity_sweep", c, cfgc,
                           background_hz=DIST_RATE)
    sync = build_synapses(c, cfgc)

    def run_chunked_sim(K, ckpt_dir=None):
        res = simulate(c, cfgc, t_steps, seed=0, syn=sync, stimulus=stimc,
                       chunk_steps=K, checkpoint_dir=ckpt_dir)
        jax.block_until_ready(res.counts)
        return res

    _run_sim(c, cfgc, sync, stimc, t_steps)
    t_mono = timeit(lambda: _run_sim(c, cfgc, sync, stimc, t_steps), iters=2)
    for K in chunk_ks:
        run_chunked_sim(K)
        t_c = timeit(lambda: run_chunked_sim(K), iters=2)
        over = (t_c - t_mono) / t_mono * 100
        rows.append(row(f"engine_step.event.chunked.{K}",
                        f"{t_steps/t_c:.1f}",
                        f"steps/sec ({t_c/t_steps*1e3:.3f} ms/step, n={c.n}, "
                        f"K={K}, rate={DIST_RATE}hz; {over:+.1f}% vs "
                        f"monolithic {t_steps/t_mono:.1f} steps/sec — "
                        f"bit-identical chunked scan)"))
    import tempfile
    with tempfile.TemporaryDirectory() as _ckdir:
        K = chunk_ks[0]
        run_chunked_sim(K, _ckdir)
        t_ck = timeit(lambda: run_chunked_sim(K, _ckdir), iters=2)
        over = (t_ck - t_mono) / t_mono * 100
        rows.append(row(f"engine_step.event.chunked.{K}.checkpointed",
                        f"{t_steps/t_ck:.1f}",
                        f"steps/sec ({t_ck/t_steps*1e3:.3f} ms/step, n={c.n}, "
                        f"K={K}; atomic npz checkpoint at every chunk "
                        f"boundary, {over:+.1f}% vs monolithic)"))

    # --- telemetry overhead (repro.obs): the identical chunked run with
    #     an async JSONL event stream attached.  The layer's contract is
    #     host-side, O(1) per chunk, bit-identical results — so the
    #     streamed-events cost must stay within noise of the bare chunked
    #     run (target < 2% of step time; docs/observability.md) ---
    import os

    from repro import obs
    K = chunk_ks[0]
    run_chunked_sim(K)
    t_bare = timeit(lambda: run_chunked_sim(K), iters=2)
    with tempfile.TemporaryDirectory() as _tdir:
        def run_telemetered():
            with obs.telemetry(os.path.join(_tdir, "run.jsonl")):
                return run_chunked_sim(K)
        run_telemetered()   # warm the instrumented compile cache
        t_tele = timeit(run_telemetered, iters=2)
    over = (t_tele - t_bare) / t_bare * 100
    rows.append(row("engine_step.event.telemetry_overhead",
                    f"{over:+.1f}%",
                    f"telemetered vs bare chunked run (K={K}, n={c.n}, "
                    f"{t_steps/t_tele:.1f} vs {t_steps/t_bare:.1f} "
                    f"steps/sec; async JSONL sink, one event/chunk "
                    f"boundary — contract: < 2% of step time)"))

    # --- fused delivery->LIF (blocked_fused): one kernel per step runs
    #     spike->gather->accumulate->integrate->threshold per 128-row
    #     block; engine_step.blocked_fused.* rows pin the fused-step
    #     trajectory at the standard sweep rates (interpret mode on CPU —
    #     small n, composition canary; the VMEM-residency win is a TPU
    #     measurement) ---
    nf = 1_000 if smoke else FUSED_N
    cf = synthetic_flywire_cached(n=nf, seed=0, target_synapses=30 * nf)
    t_fused = 10 if smoke else 50
    fused_ms = {}
    for rate in FUSED_RATES:
        for engine in ("blocked", "blocked_fused"):
            cfgf = SimConfig(engine=engine, quantize_bits=9,
                             poisson_rate_hz=0.0)
            stimf = build_scenario("activity_sweep", cf, cfgf,
                                   background_hz=rate)
            synf = build_synapses(cf, cfgf)
            res = _run_sim(cf, cfgf, synf, stimf, t_fused)
            t = timeit(lambda: _run_sim(cf, cfgf, synf, stimf, t_fused),
                       iters=2)
            fused_ms[(engine, rate)] = t / t_fused * 1e3
            if engine == "blocked_fused":
                rows.append(row(
                    f"engine_step.blocked_fused.{rate}hz",
                    f"{t_fused/t:.1f}",
                    f"steps/sec interpret-mode ({t/t_fused*1e3:.3f} ms/step,"
                    f" n={nf}, scenario=activity_sweep, dropped="
                    f"{int(res.dropped)}; delivery+LIF fused in one kernel,"
                    f" currents never leave VMEM — compiled path TPU-only)"))
    # Q19.12 fused row: the Loihi-faithful int32 pipeline through the same
    # fused kernel
    cfgq = SimConfig(engine="blocked_fused", quantize_bits=9,
                     fixed_point=True, poisson_to_v=False,
                     poisson_rate_hz=0.0)
    stimq = build_scenario("activity_sweep", cf, cfgq,
                           background_hz=min(FUSED_RATES))
    synq = build_synapses(cf, cfgq)
    _run_sim(cf, cfgq, synq, stimq, t_fused)
    tq = timeit(lambda: _run_sim(cf, cfgq, synq, stimq, t_fused), iters=2)
    rows.append(row(f"engine_step.blocked_fused.fx.{min(FUSED_RATES)}hz",
                    f"{t_fused/tq:.1f}",
                    f"steps/sec interpret-mode ({tq/t_fused*1e3:.3f} "
                    f"ms/step, n={nf}, int32 Q19.12 fused path)"))
    lo = min(FUSED_RATES)
    rows.append(row("fused.step_vs_unfused_blocked",
                    f"{fused_ms[('blocked', lo)]/fused_ms[('blocked_fused', lo)]:.2f}x",
                    f"unfused/fused ms-per-step at {lo}hz, n={nf} "
                    f"(interpret-mode composition canary; the HBM "
                    f"round-trip saving is a TPU measurement)"))

    # --- distributed exchange schemes (unified step core, emulated P=4):
    #     engine_step.dist.<scheme>.P4 extends the trajectory across the
    #     partition cut ---
    from repro.core.dcsr import build_dcsr
    from repro.core.distributed import DistConfig, simulate_distributed
    from repro.core.partition import even_partition

    dist_t = 10 if smoke else 50
    d = build_dcsr(c, even_partition(c, DIST_P))
    caps = auto_capacity(c, DIST_RATE)
    sim = SimConfig(engine="csr", poisson_rate_hz=0.0,
                    **caps.as_config_kwargs())
    stim = build_scenario("activity_sweep", c, sim, background_hz=DIST_RATE)
    for scheme in ("bitmap", "event"):
        dcfg = DistConfig(sim=sim, scheme=scheme, capacity=caps)

        def run_dist(dcfg=dcfg):
            return simulate_distributed(d, dcfg, dist_t, None, seed=0,
                                        emulate=True, stimulus=stim)
        res = run_dist()
        t = timeit(run_dist, iters=2)
        rows.append(row(f"engine_step.dist.{scheme}.P{DIST_P}",
                        f"{dist_t/t:.1f}",
                        f"steps/sec ({t/dist_t*1e3:.3f} ms/step, n={c.n}, "
                        f"P={DIST_P} emulated, rate={DIST_RATE}hz, "
                        f"dropped={int(res.dropped)})"))

    nb = 1_000 if smoke else DIST_BLOCKED_N
    cb = synthetic_flywire_cached(n=nb, seed=0, target_synapses=30 * nb)
    db = build_dcsr(cb, even_partition(cb, DIST_P))
    capsb = auto_capacity(cb, max(DIST_BLOCKED_RATES))
    tiles = {}
    t_blk = None
    for rate in DIST_BLOCKED_RATES:
        simb = SimConfig(engine="csr", poisson_rate_hz=0.0,
                         **capsb.as_config_kwargs())
        stimb = build_scenario("activity_sweep", cb, simb, background_hz=rate)
        dcfgb = DistConfig(sim=simb, scheme="blocked", capacity=capsb)

        def run_blk(dcfgb=dcfgb, stimb=stimb):
            return simulate_distributed(db, dcfgb, dist_t, None, seed=0,
                                        emulate=True, stimulus=stimb)
        res = run_blk()
        tiles[rate] = (int(res.stats["tiles_live"]),
                       int(res.stats["tiles_skipped"]))
        if rate == min(DIST_BLOCKED_RATES):
            t_blk = timeit(run_blk, iters=1)
    stored = sum(tiles[min(DIST_BLOCKED_RATES)]) // dist_t
    skipped = {r: tiles[r][1] / dist_t for r in DIST_BLOCKED_RATES}
    lo, hi = min(DIST_BLOCKED_RATES), max(DIST_BLOCKED_RATES)
    rows.append(row(f"engine_step.dist.blocked.P{DIST_P}",
                    f"{dist_t/t_blk:.1f}",
                    f"steps/sec interpret-mode (n={nb}, P={DIST_P} emulated; "
                    f"tiles skipped/step of {stored} stored: "
                    f"{skipped[lo]:.0f} @{lo}hz vs {skipped[hi]:.0f} @{hi}hz "
                    f"— skip ∝ sparsity; compiled tile path is TPU-only)"))

    # --- stimulus diversity: steps/sec per registry scenario ---
    for scen, params in SCENARIOS.items():
        for name, base in engines_for(c, params.get("background_hz", 0.5)
                                      ).items():
            cfg = base
            stim = build_scenario(scen, c, cfg, **params)
            syn = build_synapses(c, cfg)
            res = _run_sim(c, cfg, syn, stim, t_steps)
            t = timeit(lambda: _run_sim(c, cfg, syn, stim, t_steps), iters=2)
            rows.append(row(f"engine_step.{base.engine}.scenario.{scen}",
                            f"{t_steps/t:.1f}",
                            f"steps/sec ({t/t_steps*1e3:.3f} ms/step, "
                            f"n={c.n}, dropped={int(res.dropped)})"))

    # --- the paper's headline ratios ---
    for rate in (0.5, 40.0):
        ratio = times[("csr(conventional)", rate)] / \
            times[("event(loihi-like)", rate)]
        rows.append(row(f"fig17.speedup_event_vs_csr.{rate}hz",
                        f"{ratio:.2f}x",
                        "paper: advantage grows as activity sparsifies"))
    flat = times[("csr(conventional)", 40.0)] / \
        times[("csr(conventional)", 0.5)]
    scal = times[("event(loihi-like)", 40.0)] / \
        times[("event(loihi-like)", 0.5)]
    rows.append(row("fig16.csr_40hz_over_0.5hz", f"{flat:.2f}x",
                    "conventional: ~flat in activity (paper: 1.4x)"))
    rows.append(row("fig16.event_40hz_over_0.5hz", f"{scal:.2f}x",
                    "event-driven: cost tracks activity (paper: ~50x)"))

    # --- spike-probe slowdown (paper §3.2.5) ---
    cfg = SimConfig(engine="event")
    stim = build_scenario("sugar_feeding", c, cfg)
    syn = build_synapses(c, cfg)
    raster = ProbeSpec(raster=True)
    t_probe = timeit(lambda: np.asarray(
        simulate(c, cfg, t_steps, seed=0, syn=syn, stimulus=stim,
                 probes=raster).raster), iters=2)
    t_free = timeit(lambda: _run_sim(c, cfg, syn, stim, t_steps), iters=2)
    rows.append(row("probe.slowdown", f"{t_probe/t_free:.2f}x",
                    "raster probe vs counters-only (paper: probes "
                    "significantly slow execution)"))
    return rows
