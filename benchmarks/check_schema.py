"""Validate BENCH_*.json files against the checked-in contract.

    python -m benchmarks.check_schema PATH [PATH ...]

Interprets the subset of JSON Schema used by ``benchmarks/schema.json``
(type / required / properties / additionalProperties / items /
minProperties / pattern-in-not) with zero dependencies, so CI can gate
the benchmark-smoke artifact on it: required meta keys present (host
stamp included — steps/sec from unidentified machines must never enter a
trajectory), every row a ``{name, value, derived}`` record, and no
``*.error`` rows (a module that raised must fail the build, not ship a
poisoned artifact).  Exit code is the number of invalid files.
"""

from __future__ import annotations

import json
import re
import sys
from pathlib import Path

_TYPES = {"object": dict, "array": list, "string": str, "boolean": bool}


def _check(node, schema: dict, path: str, errors: list[str]) -> None:
    want = schema.get("type")
    if want is not None:
        py = _TYPES[want]
        # bool is an int subclass; "boolean" must not accept ints and
        # vice versa — benchmark meta relies on real booleans
        ok = isinstance(node, py) and not (py is not bool
                                           and isinstance(node, bool))
        if not ok:
            errors.append(f"{path}: expected {want}, got "
                          f"{type(node).__name__}")
            return
    neg = schema.get("not")
    if neg and isinstance(node, str) and re.search(neg["pattern"], node):
        errors.append(f"{path}: value {node!r} matches forbidden pattern "
                      f"{neg['pattern']!r}")
    if isinstance(node, dict):
        for key in schema.get("required", []):
            if key not in node:
                errors.append(f"{path}: missing required key {key!r}")
        if len(node) < schema.get("minProperties", 0):
            errors.append(f"{path}: wants >= {schema['minProperties']} "
                          f"entries, has {len(node)}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for key, val in node.items():
            sub = props.get(key, extra if isinstance(extra, dict) else None)
            if sub:
                _check(val, sub, f"{path}.{key}", errors)
    elif isinstance(node, list) and "items" in schema:
        for i, val in enumerate(node):
            _check(val, schema["items"], f"{path}[{i}]", errors)


def validate_file(path: str, schema: dict) -> list[str]:
    try:
        payload = json.loads(Path(path).read_text())
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable ({e})"]
    errors: list[str] = []
    _check(payload, schema, "$", errors)
    return [f"{path} {e}" for e in errors]


def main(argv: list[str]) -> int:
    if not argv:
        sys.exit("usage: python -m benchmarks.check_schema BENCH.json [...]")
    schema = json.loads(
        (Path(__file__).parent / "schema.json").read_text())
    bad = 0
    for path in argv:
        errors = validate_file(path, schema)
        if errors:
            bad += 1
            print(f"FAIL {path}")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"ok   {path}")
    return bad


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
