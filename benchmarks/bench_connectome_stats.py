"""Paper Figs 2-3: weight + fan-in/out distribution statistics of the
synthetic FlyWire-statistics connectome."""

from __future__ import annotations

import numpy as np

from repro.core import synthetic_flywire_cached
from .common import BENCH_N, BENCH_SYN, row


def run(full: bool = False):
    n, syn = (139_255, 15_000_000) if full else (BENCH_N, BENCH_SYN)
    c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn)
    s = c.stats()
    rows = []
    rows.append(row("connectome.n_neurons", s["n_neurons"]))
    rows.append(row("connectome.n_synapses", s["n_synapses"]))
    rows.append(row("connectome.max_fan_in", s["max_fan_in"],
                    "paper: 10,356 at full scale"))
    rows.append(row("connectome.max_fan_out", s["max_fan_out"],
                    "paper: 9,783"))
    rows.append(row("connectome.frac_w_pm1", f"{s['frac_w_pm1']:.3f}",
                    "paper Fig2: large mode at +-1"))
    rows.append(row("connectome.w_range", f"{s['w_min']}..{s['w_max']}",
                    "paper: -2405..1897"))
    rows.append(row("connectome.frac_inhibitory",
                    f"{s['frac_inhibitory']:.3f}", "Dale's law per source"))
    fi = c.fan_in
    rows.append(row("connectome.fan_in_p50_p99_max",
                    f"{int(np.percentile(fi,50))}/"
                    f"{int(np.percentile(fi,99))}/{fi.max()}",
                    "heavy tail (Fig 3)"))
    return rows
