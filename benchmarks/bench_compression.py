"""Paper Fig 7: effective fan-in/out under the two compression schemes."""

from __future__ import annotations

import numpy as np

from repro.core import (CoreBudget, caps_from_budget, compression_report,
                        greedy_partition, synthetic_flywire_cached)
from .common import BENCH_N, BENCH_SYN, row


def run(full: bool = False):
    n, syn = (139_255, 15_000_000) if full else (BENCH_N, BENCH_SYN)
    c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn)
    caps = caps_from_budget(CoreBudget.loihi2(), "sar")
    p = greedy_partition(c, caps, scheme="sar")
    rep = compression_report(c, p.part_of_neuron, bits=9)
    rows = []
    rows.append(row("fig7.raw_max_fan_in", rep["raw_max_fan_in"],
                    "paper: 10,356"))
    rows.append(row("fig7.sar_max_eff_fan_in", rep["sar_max_eff_fan_in"],
                    "paper: 165 (<=512 theoretical)"))
    rows.append(row("fig7.sar_reduction",
                    f"{rep['raw_max_fan_in']/max(1,rep['sar_max_eff_fan_in']):.1f}x",
                    "paper: ~63x on the outlier"))
    rows.append(row("fig7.sar_memory_ratio",
                    f"{rep['sar_memory_ratio']:.3f}",
                    "unique-(w,target) entries / synapses"))
    rows.append(row("fig7.ssd_max_eff_fan_out", rep["ssd_max_eff_fan_out"],
                    "distinct target cores per source"))
    rows.append(row("fig7.ssd_message_ratio",
                    f"{rep['ssd_message_ratio']:.3f}",
                    "messages / synapses (aggregation win)"))
    return rows
