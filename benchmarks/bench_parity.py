"""Paper Figs 6/12/13/14/15: spike-rate parity across implementation
variants, including the two approximation ablations (conductance-only
inputs, capped weights) and the 1 ms timestep variant."""

from __future__ import annotations

import numpy as np

from repro.core import SimConfig, parity, simulate, synthetic_flywire_cached
from repro.core.neuron import FLYWIRE_LIF, FLYWIRE_LIF_1MS
from .common import row

N, SYN, T, TRIALS = 8_000, 240_000, 1000, 3


def rates(c, cfg, sugar, trials=TRIALS, t=T):
    dt = cfg.params.dt
    out = [np.asarray(simulate(c, cfg, t, sugar, seed=100 + i).counts)
           for i in range(trials)]
    return np.stack(out).mean(0) / (t * dt * 1e-3)


def pick_sugar(c, k=20):
    """Sugar neurons chosen among sources with outlier outgoing weights so
    the capped-weight ablation (|w|>255) actually touches the active
    pathway, as it does in the real connectome."""
    max_w = np.zeros(c.n)
    src = np.repeat(np.arange(c.n), np.diff(c.out_indptr))
    np.maximum.at(max_w, src, np.abs(c.out_weights))
    return np.argsort(-max_w)[:k]


def run(full: bool = False):
    c = synthetic_flywire_cached(n=N, seed=0, target_synapses=SYN)
    sugar = pick_sugar(c)
    base = SimConfig(engine="csr", poisson_to_v=True)      # Brian2 semantics
    r_ref = rates(c, base, sugar)
    rows = []

    variants = {
        "fig6.stacs_float": SimConfig(engine="event", poisson_to_v=True),
        "fig13.conductance_only": SimConfig(engine="csr", poisson_to_v=False),
        "fig13.capped_weights": SimConfig(engine="csr", poisson_to_v=True,
                                          quantize_bits=9),
        "fig14.loihi_behavioral": SimConfig(engine="csr", poisson_to_v=False,
                                            quantize_bits=9,
                                            fixed_point=True),
        "fig12.loihi_hw_path": SimConfig(engine="event", poisson_to_v=False,
                                         quantize_bits=9, fixed_point=True),
    }
    for name, cfg in variants.items():
        st = parity(r_ref, rates(c, cfg, sugar))
        rows.append(row(name, f"r={st.pearson_r:.4f}",
                        f"rmse={st.rmse_hz:.2f}Hz "
                        f"within1Hz={st.frac_within_1hz:.2f} "
                        f"active={st.n_active}"))

    # Fig 15: 1 ms timestep vs 0.1 ms
    cfg_1ms = SimConfig(engine="csr", poisson_to_v=False, quantize_bits=9,
                        fixed_point=True, params=FLYWIRE_LIF_1MS)
    r_1ms = rates(c, cfg_1ms, sugar, t=T // 10)
    cfg_01 = SimConfig(engine="csr", poisson_to_v=False, quantize_bits=9,
                       fixed_point=True, params=FLYWIRE_LIF)
    st = parity(rates(c, cfg_01, sugar), r_1ms)
    rows.append(row("fig15.dt1ms_vs_dt01ms", f"r={st.pearson_r:.4f}",
                    f"rmse={st.rmse_hz:.2f}Hz"))
    st = parity(r_ref, r_1ms)
    rows.append(row("fig15.dt1ms_vs_brian2", f"r={st.pearson_r:.4f}",
                    f"rmse={st.rmse_hz:.2f}Hz"))
    return rows
