"""In-scan probes: what the simulation's ``ys`` carry per step.

The paper (§3.2.5) notes that spike probes significantly slow Loihi
execution; here observability is a static :class:`ProbeSpec` that selects
which records the jitted scan stacks — pay only for what you measure.
``SimResult.records`` is a dict of ``[T, ...]`` arrays (``[B, T, ...]``
under :func:`repro.exp.run_trials`):

========== ======================= =====================================
key        shape per step          meaning
========== ======================= =====================================
raster     [n] bool                full spike raster (legacy
                                   ``collect_raster``)
v          [len(voltage)]          membrane potential of the sampled
                                   neuron subset, engine-native units
                                   (mV float path, Q19.12 fixed point —
                                   convert with ``neuron.fx_to_mv``)
pop_rate_hz scalar float32         population mean firing rate this step
dropped    scalar int32            synapse events lost to capacity limits
========== ======================= =====================================
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ProbeSpec:
    """Static (hashable) selection of per-step records; part of the jit
    cache key, so changing probes retraces but never changes semantics."""

    raster: bool = False
    voltage: tuple[int, ...] = ()    # neuron ids whose v is traced
    pop_rate: bool = False
    drops: bool = False

    def __post_init__(self):
        object.__setattr__(self, "voltage", tuple(int(i) for i in self.voltage))

    @property
    def any(self) -> bool:
        return bool(self.raster or self.voltage or self.pop_rate or self.drops)

    def collect(self, *, spikes: jax.Array, lif, drop: jax.Array,
                params, voltage_rows=None) -> dict:
        """Build this step's record dict (traced inside the scan body).

        ``voltage_rows`` optionally remaps the probe ids onto this
        partition's local rows (distributed path: every partition traces
        all probe ids against its own ``[U]`` slab, and the host keeps the
        owning partition's trace — see ``repro.core.distributed``)."""
        rec: dict = {}
        if self.raster:
            rec["raster"] = spikes
        if self.voltage:
            if voltage_rows is not None:
                rec["v"] = lif.v[voltage_rows]
            else:
                n = spikes.shape[0]
                bad = [i for i in self.voltage if not 0 <= i < n]
                if bad:
                    # jit-time check: JAX's clamping gather would otherwise
                    # silently return a different neuron's trace
                    raise ValueError(f"voltage probe ids {bad} out of range "
                                     f"for n={n}")
                rec["v"] = lif.v[jnp.asarray(self.voltage, dtype=jnp.int32)]
        if self.pop_rate:
            rec["pop_rate_hz"] = (
                spikes.astype(jnp.float32).mean() / (params.dt * 1e-3))
        if self.drops:
            rec["dropped"] = drop.astype(jnp.int32)
        return rec


NO_PROBES = ProbeSpec()

__all__ = ["NO_PROBES", "ProbeSpec"]
