"""Vmapped trial batches: the paper's 10-trial statistic in one compiled call.

The paper validates implementations by comparing per-neuron spike rates
averaged over 10 trials (Figs 6, 12, 14-15).  :func:`run_trials` vmaps the
simulation scan over a batch of seeds — one trace, one device dispatch —
and is bit-identical to a Python loop of :func:`repro.core.simulate` calls
over the same seeds.  ``mean_rates_hz`` feeds
:func:`repro.core.validate.parity` directly.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connectome import Connectome
from repro.core.engine import (SimConfig, _init_carry, _resolve_probes,
                               _resolve_stimulus, _run_scan_trials,
                               build_synapses)
from repro.core.neuron import LIFState


class TrialResult(NamedTuple):
    counts: jax.Array      # [B, n] per-trial spike counts
    dropped: jax.Array     # [B]
    state: LIFState        # leaves [B, n]
    records: dict          # probe records, each [B, T, ...]
    seeds: tuple           # the seeds, in batch order

    def rates_hz(self, t_steps: int, dt_ms: float) -> np.ndarray:
        """[B, n] per-trial per-neuron rates."""
        return np.asarray(self.counts, np.float64) / (t_steps * dt_ms * 1e-3)

    def mean_rates_hz(self, t_steps: int, dt_ms: float) -> np.ndarray:
        """[n] trial-averaged rates — the parity-plot statistic."""
        return self.rates_hz(t_steps, dt_ms).mean(axis=0)


def run_trials(
    c: Connectome,
    cfg: SimConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seeds: int | Sequence[int] = 10,
    syn: Any | None = None,
    stimulus: Any | None = None,
    probes: Any | None = None,
) -> TrialResult:
    """Run one trial per seed as a single vmapped, jitted scan.

    ``seeds`` is either a trial count B (seeds 0..B-1) or an explicit
    sequence.  Synaptic state and the stimulus are shared (broadcast)
    across trials; each trial gets its own PRNG stream, exactly as
    ``simulate(..., seed=s)`` would.
    """
    if isinstance(seeds, (int, np.integer)):
        seeds = tuple(range(int(seeds)))
    else:
        seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("run_trials needs at least one seed")
    n = c.n
    if syn is None:
        syn = build_synapses(c, cfg)
    stimulus = _resolve_stimulus(cfg, n, sugar_neurons, stimulus)
    probes = _resolve_probes(cfg, probes)

    tmpl = _init_carry(n, cfg, stimulus, 0)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    B = len(seeds)
    carry = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (B,) + x.shape).copy(), tmpl)
    carry = carry._replace(key=keys)

    carry, records = _run_scan_trials(syn, carry, stimulus, cfg, probes,
                                      t_steps, n)
    return TrialResult(counts=carry.counts, dropped=carry.dropped,
                       state=carry.lif, records=records, seeds=seeds)


__all__ = ["TrialResult", "run_trials"]
