"""Vmapped trial batches: the paper's 10-trial statistic in one compiled call.

The paper validates implementations by comparing per-neuron spike rates
averaged over 10 trials (Figs 6, 12, 14-15).  :func:`run_trials` vmaps the
simulation scan over a batch of seeds — one trace, one device dispatch —
and is bit-identical to a Python loop of :func:`repro.core.simulate` calls
over the same seeds.  :func:`run_dist_trials` is the same batching on the
partitioned path (the unified step core makes it the same scan): the
trial axis is vmapped *inside* each partition, so one emulated or
shard_map dispatch covers the whole seed batch.  ``mean_rates_hz`` feeds
:func:`repro.core.validate.parity` directly.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connectome import Connectome
from repro.core.engine import (SimConfig, _init_carry, _resolve_probes,
                               _resolve_stimulus, _run_scan_trials,
                               build_synapses)
from repro.core.health import run_chunked
from repro.core.neuron import LIFState


def _seed_tuple(seeds) -> tuple:
    if isinstance(seeds, (int, np.integer)):
        seeds = tuple(range(int(seeds)))
    else:
        seeds = tuple(int(s) for s in seeds)
    if not seeds:
        raise ValueError("need at least one seed")
    return seeds


def trial_carry(n: int, cfg: SimConfig, stimulus, seeds):
    """Trial-batched scan carry: the single-run carry broadcast over a
    leading seed axis, with one PRNG stream per trial — exactly what
    ``simulate(..., seed=s)`` initializes, stacked.  Returns
    ``(carry, seeds)`` with ``seeds`` normalized to a tuple.  Shared by
    :func:`run_trials` and the serving layer's request batching
    (:mod:`repro.serving.sim`), which packs independent requests into
    the same vmapped scan."""
    seeds = _seed_tuple(seeds)
    tmpl = _init_carry(n, cfg, stimulus, 0)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in seeds])
    carry = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (len(seeds),) + x.shape).copy(), tmpl)
    return carry._replace(key=keys), seeds


class TrialResult(NamedTuple):
    counts: jax.Array      # [B, n] per-trial spike counts
    dropped: jax.Array     # [B]
    state: LIFState        # leaves [B, n]
    records: dict          # probe records, each [B, T, ...]
    seeds: tuple           # the seeds, in batch order

    def rates_hz(self, t_steps: int, dt_ms: float) -> np.ndarray:
        """[B, n] per-trial per-neuron rates."""
        return np.asarray(self.counts, np.float64) / (t_steps * dt_ms * 1e-3)

    def mean_rates_hz(self, t_steps: int, dt_ms: float) -> np.ndarray:
        """[n] trial-averaged rates — the parity-plot statistic."""
        return self.rates_hz(t_steps, dt_ms).mean(axis=0)


def run_trials(
    c: Connectome,
    cfg: SimConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seeds: int | Sequence[int] = 10,
    syn: Any | None = None,
    stimulus: Any | None = None,
    probes: Any | None = None,
    chunk_steps: int | None = None,
) -> TrialResult:
    """Run one trial per seed as a single vmapped, jitted scan.

    ``seeds`` is either a trial count B (seeds 0..B-1) or an explicit
    sequence.  Synaptic state and the stimulus are shared (broadcast)
    across trials; each trial gets its own PRNG stream, exactly as
    ``simulate(..., seed=s)`` would.

    ``chunk_steps=K`` supervises the batch the same way ``simulate()``
    does (:func:`repro.core.health.run_chunked`): ceil(T/K) reuses of one
    compiled K-step program, bit-identical to the monolithic scan, with
    ``cfg.health`` thresholds checked at chunk boundaries against the
    counters summed over the whole batch (per-lane attribution is the
    serving layer's job — :mod:`repro.serving.sim`).
    """
    n = c.n
    if syn is None:
        syn = build_synapses(c, cfg)
    stimulus = _resolve_stimulus(cfg, n, sugar_neurons, stimulus)
    probes = _resolve_probes(cfg, probes)
    carry, seeds = trial_carry(n, cfg, stimulus, seeds)

    if chunk_steps:
        def run_chunk(cy, s, k):
            return _run_scan_trials(syn, cy, stimulus, cfg, probes, k, n,
                                    jnp.int32(s))
        # records are [B, T, ...] on the batched path -> time axis 1; the
        # rate envelope normalizes by the batch-summed neuron count
        carry, records = run_chunked(
            run_chunk, carry, t_steps, chunk_steps, time_axis=1,
            health=cfg.health, n=n * len(seeds), dt_ms=cfg.params.dt)
    else:
        carry, records = _run_scan_trials(syn, carry, stimulus, cfg, probes,
                                          t_steps, n)
    return TrialResult(counts=carry.counts, dropped=carry.dropped,
                       state=carry.lif, records=records, seeds=seeds)


class DistTrialResult(NamedTuple):
    """Trial-batched distributed run; per-neuron data in original ids."""
    counts: np.ndarray     # [B, n_orig] per-trial spike counts
    dropped: np.ndarray    # [B]
    state: Any             # LIFState, leaves [B, n_orig]
    records: dict          # probe records, each [B, T, ...] (original ids)
    stats: dict            # scheme counters, each [B]
    seeds: tuple

    def rates_hz(self, t_steps: int, dt_ms: float) -> np.ndarray:
        """[B, n] per-trial per-neuron rates."""
        return np.asarray(self.counts, np.float64) / (t_steps * dt_ms * 1e-3)

    def mean_rates_hz(self, t_steps: int, dt_ms: float) -> np.ndarray:
        """[n] trial-averaged rates — the parity-plot statistic."""
        return self.rates_hz(t_steps, dt_ms).mean(axis=0)


def run_dist_trials(
    d,
    cfg,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seeds: int | Sequence[int] = 10,
    stimulus: Any | None = None,
    probes: Any | None = None,
    mesh=None,
    emulate: bool = False,
) -> DistTrialResult:
    """Distributed counterpart of :func:`run_trials`: one partitioned
    dispatch (vmap emulation or shard_map) covering the whole seed batch,
    bit-identical to a Python loop of
    :func:`repro.core.distributed.simulate_distributed` over the same
    seeds.  ``d`` is a :class:`repro.core.dcsr.DCSR`, ``cfg`` a
    :class:`repro.core.distributed.DistConfig`."""
    from repro.core.distributed import _assemble, _run_partitioned
    seeds = _seed_tuple(seeds)
    # keys[p, b] = what simulate_distributed(seed=seeds[b]) hands part p
    keys = jnp.stack(
        [jax.random.split(jax.random.PRNGKey(s), d.n_parts) for s in seeds],
        axis=1)                                          # [P, B, 2]
    out, records, probes, owner = _run_partitioned(
        d, cfg, t_steps, keys, sugar_neurons, stimulus, probes, mesh,
        emulate, trials=True)
    counts, dropped, state, recs, stats = _assemble(d, out, records, probes,
                                                    owner)
    return DistTrialResult(counts=counts, dropped=np.asarray(dropped),
                           state=state, records=recs, stats=stats,
                           seeds=seeds)


__all__ = ["DistTrialResult", "TrialResult", "run_dist_trials", "run_trials",
           "trial_carry"]
