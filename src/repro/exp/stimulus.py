"""Composable stimulus protocols: what drives the network each step.

The paper's validation workload is one hard-coded scenario — Poisson drive
onto the sugar-sensing population plus optional uniform background spiking.
This module makes stimulation a first-class pluggable subsystem (the
counterpart of the delivery-engine registry for *input* rather than
*synapses*): a :class:`Stimulus` is a pytree (arrays are traced children,
rates/windows are static aux data keying the jit cache) whose ``step``
produces the per-step :class:`StimDrive` consumed by the simulation loop.

Drive channels (all optional, combined additively / by OR):

* ``v_mv``    — direct membrane drive in mV (Brian2-style Poisson semantics);
* ``g_units`` — synaptic drive in integer weight units (Loihi approximation);
* ``force``   — forced spikes this step (the scaling study's background).

RNG contract: the simulation step splits its carry key into
``1 + max(2, stimulus.n_keys)`` subkeys and hands ``keys[1:]`` to the
stimulus.  :func:`legacy_stimulus` reconstructs the pre-subsystem inline
drive with exactly the historical key layout (sugar Poisson consumes
``keys[1]``, background consumes ``keys[2]``), so ``PoissonDrive`` is
bit-identical — same seed, same counts — to the deleted sugar branch on
both the float and fixed-point paths.

Distributed use: :func:`shard_stimulus` converts any stimulus to its dense
per-neuron ("masked") form and remaps every per-neuron leaf through a DCSR
partitioning into partition-stacked ``[P, U]`` arrays, so the shard_map
simulator consumes the same stimulus pytrees (stateless stimuli only).
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engines.base import register_state, static_field
from repro.core.neuron import LIFParams, lif_step, lif_step_fx, poisson_drive


class StimDrive(NamedTuple):
    """Per-step drive; ``None`` channels cost nothing in the trace."""

    v_mv: jax.Array | None = None      # [n] float32 membrane drive, mV
    g_units: jax.Array | None = None   # [n] float32 synaptic drive, weight units
    force: jax.Array | None = None     # [n] bool forced spikes


@runtime_checkable
class Stimulus(Protocol):
    """One stimulation strategy (see module docstring).

    ``n_keys`` is the number of PRNG subkeys consumed per step (0 for
    deterministic stimuli); ``step`` receives a ``[n_keys, ...]`` slice of
    the per-step key split (index ``keys[0]`` in leaves).
    """

    n_keys: int

    def init_state(self, n: int) -> Any:
        """Per-run stimulus state pytree (``()`` for stateless stimuli)."""
        ...

    def step(self, state: Any, keys: jax.Array | None, t: jax.Array, n: int,
             p: LIFParams) -> tuple[Any, StimDrive]:
        ...

    def to_masked(self, n: int) -> "Stimulus":
        """Equivalent stimulus whose neuron selectors are dense ``[n]``
        arrays (required for :func:`shard_stimulus`; may change the RNG
        stream for scatter-mode stimuli)."""
        ...


def n_split(stim) -> int:
    """Subkeys to split from the carry key each step: 1 (next carry) plus
    one per stimulus key, floored at the historical 3-way split so every
    legacy configuration keeps its exact PRNG stream.  Both the monolithic
    and distributed step bodies call this — the key-layout contract lives
    here only."""
    return 1 + max(2, stim.n_keys)


def apply_drive(lif, g_units: jax.Array, drive: StimDrive, p: LIFParams,
                fixed_point: bool):
    """Apply a :class:`StimDrive` to the delivered synaptic input and
    integrate one LIF step -> ``(new_lif, spikes)``.

    Shared by the monolithic and distributed step bodies so the
    bit-compat-pinned arithmetic — g add before fixed-point rounding, the
    Q19.12 conversion of ``v_mv`` — lives in exactly one place."""
    if drive.g_units is not None:
        g_units = g_units + drive.g_units
    if fixed_point:
        g_in = jnp.round(g_units).astype(jnp.int32)
        v_fx = None
        if drive.v_mv is not None:
            v_fx = jnp.round(drive.v_mv / p.w_scale).astype(jnp.int32)
        return lif_step_fx(lif, g_in, p, v_fx, drive.force)
    return lif_step(lif, g_units * p.w_scale, p, drive.v_mv, drive.force)


def per_neuron(sel, amp, n: int) -> jax.Array:
    """Dense [n] float32 drive: ids or bool mask ``sel`` set to ``amp``."""
    w = np.zeros(n, np.float32)
    w[np.asarray(sel)] = amp
    return jnp.asarray(w)


def _by_target(target: str, arr: jax.Array) -> StimDrive:
    if target == "v":
        return StimDrive(v_mv=arr)
    if target == "g":
        return StimDrive(g_units=arr)
    raise ValueError(f"unknown drive target {target!r} (want 'v' or 'g')")


# --------------------------------------------------------------------------
# Stochastic stimuli
# --------------------------------------------------------------------------

@register_state
@dataclasses.dataclass(frozen=True)
class PoissonDrive:
    """Bernoulli(rate*dt) drive onto a population (the sugar experiment).

    Scatter mode (``idx``) draws only for the driven subset — the exact
    historical sugar branch.  Masked mode (``mask`` or neither) draws for
    all n and masks — the distributed-friendly form (different RNG stream,
    same distribution).  ``target='v'`` forces the membrane above threshold
    (Brian2 semantics, amp = 1.5*v_th unless overridden); ``target='g'``
    adds ``weight`` units of synaptic drive (Loihi approximation) — the
    paper's Fig 13 ablation toggles exactly this.
    """

    idx: Any = None                               # [k] int32 target ids
    mask: Any = None                              # [n] bool
    rate_hz: float = static_field(default=150.0)
    target: str = static_field(default="v")       # "v" | "g"
    v_amp_mv: float | None = static_field(default=None)  # None -> 1.5*v_th
    weight: float = static_field(default=180.0)   # g units per event

    n_keys = 1

    def init_state(self, n: int):
        return ()

    def step(self, state, keys, t, n, p):
        prob = self.rate_hz * p.dt * 1e-3
        amp = (1.5 * p.v_th) if self.v_amp_mv is None else self.v_amp_mv
        if self.idx is not None:
            draws = jax.random.bernoulli(keys[0], prob, self.idx.shape)
            if self.target == "v":
                v = jnp.zeros(n, jnp.float32).at[self.idx].set(
                    draws.astype(jnp.float32) * amp)
                return state, StimDrive(v_mv=v)
            g = jnp.zeros(n, jnp.float32).at[self.idx].add(
                draws.astype(jnp.float32) * self.weight)
            return state, StimDrive(g_units=g)
        draws = poisson_drive(keys[0], n, self.rate_hz, p.dt, self.mask)
        if self.target == "v":
            return state, StimDrive(v_mv=draws.astype(jnp.float32) * amp)
        return state, StimDrive(g_units=draws.astype(jnp.float32) * self.weight)

    def to_masked(self, n: int):
        if self.idx is None:
            mask = jnp.ones(n, bool) if self.mask is None else self.mask
        else:
            m = np.zeros(n, bool)
            m[np.asarray(self.idx)] = True
            mask = jnp.asarray(m)
        return dataclasses.replace(self, idx=None, mask=mask)


@register_state
@dataclasses.dataclass(frozen=True)
class Background:
    """Probabilistic background spiking (the activity scaling study):
    every unmasked neuron emits a forced spike with prob rate*dt."""

    mask: Any = None                              # [n] bool, None = all
    rate_hz: float = static_field(default=5.0)

    n_keys = 1

    def init_state(self, n: int):
        return ()

    def step(self, state, keys, t, n, p):
        return state, StimDrive(
            force=poisson_drive(keys[0], n, self.rate_hz, p.dt, self.mask))

    def to_masked(self, n: int):
        mask = jnp.ones(n, bool) if self.mask is None else self.mask
        return dataclasses.replace(self, mask=mask)


@register_state
@dataclasses.dataclass(frozen=True)
class SkipKey:
    """Consume one PRNG subkey and drive nothing.

    Placeholder reproducing the historical key layout: the old inline step
    always split 3 keys even when a drive branch was absent, so e.g. a
    background-only legacy run drew from ``keys[2]``.
    """

    n_keys = 1

    def init_state(self, n: int):
        return ()

    def step(self, state, keys, t, n, p):
        return state, StimDrive()

    def to_masked(self, n: int):
        return self


# --------------------------------------------------------------------------
# Deterministic (clocked) stimuli
# --------------------------------------------------------------------------

@register_state
@dataclasses.dataclass(frozen=True)
class StepCurrent:
    """Constant drive ``weights`` during the window [t_on, t_off)."""

    weights: Any                                   # [n] float32 amplitude
    t_on: int = static_field(default=0)            # steps
    t_off: int | None = static_field(default=None)
    target: str = static_field(default="g")

    n_keys = 0

    def init_state(self, n: int):
        return ()

    def step(self, state, keys, t, n, p):
        on = t >= self.t_on
        if self.t_off is not None:
            on = jnp.logical_and(on, t < self.t_off)
        return state, _by_target(self.target, self.weights * on.astype(jnp.float32))

    def to_masked(self, n: int):
        return self


@register_state
@dataclasses.dataclass(frozen=True)
class PulseTrain:
    """Periodic pulses: ``width``-step pulses every ``period`` steps from
    ``t_on``, optionally limited to ``n_pulses``."""

    weights: Any
    period: int = static_field(default=100)        # steps
    width: int = static_field(default=5)           # steps
    t_on: int = static_field(default=0)
    n_pulses: int | None = static_field(default=None)
    target: str = static_field(default="g")

    n_keys = 0

    def init_state(self, n: int):
        return ()

    def step(self, state, keys, t, n, p):
        ph = t - self.t_on
        on = ph >= 0
        if self.n_pulses is not None:
            on = jnp.logical_and(on, ph < self.n_pulses * self.period)
        on = jnp.logical_and(on, ph % self.period < self.width)
        return state, _by_target(self.target, self.weights * on.astype(jnp.float32))

    def to_masked(self, n: int):
        return self


@register_state
@dataclasses.dataclass(frozen=True)
class RampDrive:
    """Optogenetic-style windowed ramp: amplitude rises linearly from 0 to
    ``weights`` over ``t_ramp`` steps starting at ``t_on``, holds, and cuts
    off at ``t_off`` (None = never)."""

    weights: Any
    t_on: int = static_field(default=0)
    t_ramp: int = static_field(default=100)        # steps to reach peak
    t_off: int | None = static_field(default=None)
    target: str = static_field(default="g")

    n_keys = 0

    def init_state(self, n: int):
        return ()

    def step(self, state, keys, t, n, p):
        ph = t - self.t_on
        frac = jnp.clip(ph.astype(jnp.float32) / max(self.t_ramp, 1), 0.0, 1.0)
        gate = jnp.where(ph >= 0, frac, 0.0)
        if self.t_off is not None:
            gate = jnp.where(t < self.t_off, gate, 0.0)
        return state, _by_target(self.target, self.weights * gate)

    def to_masked(self, n: int):
        return self


# --------------------------------------------------------------------------
# Composition
# --------------------------------------------------------------------------

@register_state
@dataclasses.dataclass(frozen=True)
class Compose:
    """Combine stimuli: v/g drives add, forced spikes OR.  PRNG subkeys are
    distributed to parts in declaration order (each part consumes
    ``part.n_keys``), which is what makes legacy key layouts expressible."""

    parts: tuple = ()

    @property
    def n_keys(self) -> int:
        return sum(s.n_keys for s in self.parts)

    def init_state(self, n: int):
        return tuple(s.init_state(n) for s in self.parts)

    def step(self, state, keys, t, n, p):
        if len(state) != len(self.parts):
            raise ValueError(
                f"Compose state has {len(state)} entries for "
                f"{len(self.parts)} parts — carry was not built from this "
                f"stimulus's init_state()")
        v = g = force = None
        new_states = []
        k0 = 0
        for s, st in zip(self.parts, state):
            ks = keys[k0:k0 + s.n_keys] if s.n_keys else None
            k0 += s.n_keys
            st2, d = s.step(st, ks, t, n, p)
            new_states.append(st2)
            if d.v_mv is not None:
                v = d.v_mv if v is None else v + d.v_mv
            if d.g_units is not None:
                g = d.g_units if g is None else g + d.g_units
            if d.force is not None:
                force = d.force if force is None else jnp.logical_or(force, d.force)
        return tuple(new_states), StimDrive(v_mv=v, g_units=g, force=force)

    def to_masked(self, n: int):
        return Compose(tuple(s.to_masked(n) for s in self.parts))


SILENT = Compose(())   # no external drive at all (silent_baseline scenario)


# --------------------------------------------------------------------------
# Legacy reconstruction + distributed sharding
# --------------------------------------------------------------------------

def legacy_stimulus(cfg, n: int, sugar_idx=None, masked: bool = False) -> Compose:
    """Reconstruct the pre-subsystem inline drive from SimConfig fields.

    ``masked=False`` mirrors the monolithic ``_run_scan`` (scatter-mode
    sugar Poisson iff ``sugar_idx`` given); ``masked=True`` mirrors the
    historical distributed step (masked Poisson iff ``poisson_rate_hz > 0``,
    mask possibly empty).  Both reproduce the historical key layout
    bit-for-bit (see :class:`SkipKey`).
    """
    parts: list = []
    if masked:
        if cfg.poisson_rate_hz > 0:
            m = np.zeros(n, bool)
            if sugar_idx is not None:
                m[np.asarray(sugar_idx)] = True
            parts.append(PoissonDrive(
                mask=jnp.asarray(m), rate_hz=cfg.poisson_rate_hz,
                target="v" if cfg.poisson_to_v else "g",
                weight=cfg.poisson_weight))
    elif sugar_idx is not None:
        parts.append(PoissonDrive(
            idx=jnp.asarray(np.asarray(sugar_idx).astype(np.int32)),
            rate_hz=cfg.poisson_rate_hz,
            target="v" if cfg.poisson_to_v else "g",
            weight=cfg.poisson_weight))
    if cfg.background_rate_hz > 0:
        if not parts:
            parts.append(SkipKey())
        parts.append(Background(rate_hz=cfg.background_rate_hz))
    return Compose(tuple(parts))


def shard_stimulus(stim, d):
    """Remap a stimulus onto a DCSR partitioning for the shard_map path.

    Converts to masked form, then turns every per-neuron leaf ``[..., n]``
    into partition-stacked ``[..., P, U]`` via the DCSR renumbering (pad
    neurons get zeros/False — exactly the pad masking the distributed step
    applies to spikes).  Static aux data is untouched.
    """
    dense = stim.to_masked(d.n_orig)
    P_, U = d.n_parts, d.part_size
    inv = np.asarray(d.inv_perm)
    safe = np.where(inv >= 0, inv, 0)

    def remap(x):
        x = np.asarray(x)
        if x.ndim >= 1 and x.shape[-1] == d.n_orig:
            out = np.where(inv >= 0, x[..., safe], np.zeros((), x.dtype))
            return jnp.asarray(out.reshape(x.shape[:-1] + (P_, U)))
        return jnp.asarray(x)

    return jax.tree.map(remap, dense)


__all__ = [
    "Background", "Compose", "PoissonDrive", "PulseTrain", "RampDrive",
    "SILENT", "SkipKey", "StepCurrent", "StimDrive", "Stimulus",
    "apply_drive", "legacy_stimulus", "n_split", "per_neuron",
    "shard_stimulus",
]
