"""Named-scenario registry: reusable stimulus-response experiments.

A *scenario* is a named builder ``build(c, cfg, **params) -> Stimulus``
with documented, overridable defaults — the stimulus-side analogue of the
delivery-engine registry.  The CLI (``repro.launch.simulate --scenario``),
benchmarks, and examples all draw from the same catalog, so a scenario
defined once runs monolithic, vmapped over trials, or distributed
(via :func:`repro.exp.shard_stimulus`) unchanged.

Catalog (see docs/experiments.md):

================== ======================================================
sugar_feeding      the paper's validation workload: Poisson drive onto a
                   random sugar-sensing population (+ optional background)
activity_sweep     uniform background spiking at a parametric rate — the
                   Table 1 / Figs 16-17 scaling-study substrate
background_storm   sugar drive under heavy background (stress / drop
                   accounting regime)
silent_baseline    no external drive: a correctly wired network must stay
                   silent (regression canary)
step_response      constant current step onto a random subset in a window
pulse_probe        periodic pulse train onto a random subset
opto_ramp          optogenetic-style windowed linear ramp drive
================== ======================================================
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from .stimulus import (Background, Compose, PoissonDrive, PulseTrain,
                       RampDrive, SILENT, StepCurrent, per_neuron)


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    description: str
    build: Callable[..., Any]        # (c, cfg, **params) -> Stimulus
    defaults: dict


_SCENARIOS: dict[str, Scenario] = {}


def register_scenario(name: str, description: str = "", **defaults):
    """Decorator: register ``fn(c, cfg, **params) -> Stimulus`` under
    ``name`` with overridable default params."""
    def deco(fn):
        if name in _SCENARIOS:
            raise ValueError(f"scenario {name!r} already registered")
        _SCENARIOS[name] = Scenario(name, description, fn, dict(defaults))
        return fn
    return deco


def get_scenario(name: str) -> Scenario:
    try:
        return _SCENARIOS[name]
    except KeyError:
        raise ValueError(
            f"unknown scenario {name!r}; available: {sorted(_SCENARIOS)}"
        ) from None


def available_scenarios() -> list[str]:
    return sorted(_SCENARIOS)


def build_scenario(name: str, c, cfg, **overrides):
    """Instantiate a named scenario's stimulus for connectome ``c`` under
    ``cfg`` (params default from the registry, overridable per call)."""
    s = get_scenario(name)
    unknown = set(overrides) - set(s.defaults)
    if unknown:
        raise ValueError(f"scenario {name!r} has no params {sorted(unknown)}; "
                         f"accepts {sorted(s.defaults)}")
    return s.build(c, cfg, **{**s.defaults, **overrides})


def _pick(c, n_targets: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.choice(c.n, size=min(int(n_targets), c.n), replace=False)


# --------------------------------------------------------------------------
# Catalog
# --------------------------------------------------------------------------

@register_scenario(
    "sugar_feeding",
    "paper validation workload: Poisson onto sugar-sensing neurons",
    n_sugar=20, rate_hz=None, background_hz=0.0, seed=0)
def _sugar_feeding(c, cfg, *, n_sugar, rate_hz, background_hz, seed):
    idx = _pick(c, n_sugar, seed)
    parts = [PoissonDrive(
        idx=jnp.asarray(idx.astype(np.int32)),
        rate_hz=cfg.poisson_rate_hz if rate_hz is None else rate_hz,
        target="v" if cfg.poisson_to_v else "g",
        weight=cfg.poisson_weight)]
    if background_hz > 0:
        parts.append(Background(rate_hz=background_hz))
    return Compose(tuple(parts))


@register_scenario(
    "activity_sweep",
    "uniform background spiking at a parametric rate (scaling study)",
    background_hz=5.0)
def _activity_sweep(c, cfg, *, background_hz):
    if background_hz <= 0:      # off = no per-step draw at all
        return SILENT
    return Compose((Background(rate_hz=background_hz),))


@register_scenario(
    "background_storm",
    "sugar drive under heavy background activity (stress regime)",
    n_sugar=20, background_hz=200.0, seed=0)
def _background_storm(c, cfg, *, n_sugar, background_hz, seed):
    sugar = build_scenario("sugar_feeding", c, cfg, n_sugar=n_sugar, seed=seed)
    return Compose(sugar.parts + (Background(rate_hz=background_hz),))


@register_scenario(
    "silent_baseline",
    "no external drive: the network must stay silent",
)
def _silent_baseline(c, cfg):
    return SILENT


@register_scenario(
    "step_response",
    "constant current step onto a random subset during a window",
    n_targets=100, amp=80.0, t_on=50, t_off=250, seed=0)
def _step_response(c, cfg, *, n_targets, amp, t_on, t_off, seed):
    w = per_neuron(_pick(c, n_targets, seed), amp, c.n)
    return Compose((StepCurrent(weights=w, t_on=int(t_on), t_off=int(t_off)),))


@register_scenario(
    "pulse_probe",
    "periodic pulse train onto a random subset",
    n_targets=100, amp=120.0, period_ms=5.0, width_ms=0.5, t_on=0, seed=0)
def _pulse_probe(c, cfg, *, n_targets, amp, period_ms, width_ms, t_on, seed):
    dt = cfg.params.dt
    w = per_neuron(_pick(c, n_targets, seed), amp, c.n)
    return Compose((PulseTrain(
        weights=w, period=max(1, int(round(period_ms / dt))),
        width=max(1, int(round(width_ms / dt))), t_on=int(t_on)),))


@register_scenario(
    "opto_ramp",
    "optogenetic-style windowed linear ramp drive",
    n_targets=200, amp=60.0, t_on_ms=5.0, ramp_ms=20.0, t_off_ms=40.0, seed=0)
def _opto_ramp(c, cfg, *, n_targets, amp, t_on_ms, ramp_ms, t_off_ms, seed):
    dt = cfg.params.dt
    w = per_neuron(_pick(c, n_targets, seed), amp, c.n)
    return Compose((RampDrive(
        weights=w, t_on=int(round(t_on_ms / dt)),
        t_ramp=max(1, int(round(ramp_ms / dt))),
        t_off=int(round(t_off_ms / dt))),))


__all__ = ["Scenario", "available_scenarios", "build_scenario",
           "get_scenario", "register_scenario"]
