"""Experiment subsystem: stimulus protocols, in-scan probes, trial batches,
and the named-scenario registry.

Layering: :mod:`repro.core` knows nothing about *which* experiment runs —
its simulation loop exposes a stimulus hook and a probe hook; this package
supplies the implementations.  See docs/experiments.md.
"""

from .probes import NO_PROBES, ProbeSpec
from .scenarios import (Scenario, available_scenarios, build_scenario,
                        get_scenario, register_scenario)
from .stimulus import (SILENT, Background, Compose, PoissonDrive, PulseTrain,
                       RampDrive, SkipKey, StepCurrent, StimDrive, Stimulus,
                       legacy_stimulus, per_neuron, shard_stimulus)
from .trials import (DistTrialResult, TrialResult, run_dist_trials,
                     run_trials, trial_carry)

__all__ = [
    "NO_PROBES", "ProbeSpec",
    "Scenario", "available_scenarios", "build_scenario", "get_scenario",
    "register_scenario",
    "SILENT", "Background", "Compose", "PoissonDrive", "PulseTrain",
    "RampDrive", "SkipKey", "StepCurrent", "StimDrive", "Stimulus",
    "legacy_stimulus", "per_neuron", "shard_stimulus",
    "DistTrialResult", "TrialResult", "run_dist_trials", "run_trials",
    "trial_carry",
]
