"""Event-record validation against the committed ``schema.json``.

Same zero-dependency philosophy as ``benchmarks/check_schema.py``: a
small interpreter over the JSON-Schema subset the committed schema uses
(type — including type lists, required, properties,
additionalProperties, items, enum), so the contract that telemetry
streams validate is enforceable in CI without installing anything.

``schema.json`` has two parts: ``common`` (every record: monotonic
``t``, a known ``type``) and ``events`` (one sub-schema per event
type, dispatched on ``type``).  Unknown extra keys are allowed unless a
sub-schema constrains them via ``additionalProperties`` — events may
grow fields without breaking old readers, but never lose required ones.
"""

from __future__ import annotations

import functools
import json
from pathlib import Path

_TYPES = {
    "object": dict,
    "array": list,
    "string": str,
    "boolean": bool,
    "integer": int,
    "number": (int, float),
    "null": type(None),
}


@functools.lru_cache(maxsize=1)
def load_schema() -> dict:
    return json.loads((Path(__file__).parent / "schema.json").read_text())


def _type_ok(node, want: str) -> bool:
    py = _TYPES[want]
    if isinstance(node, bool):
        # bool is an int subclass; "number"/"integer" must not accept it
        return want == "boolean"
    return isinstance(node, py)


def _check(node, schema: dict, path: str, errors: list[str]) -> None:
    want = schema.get("type")
    if want is not None:
        wants = want if isinstance(want, list) else [want]
        if not any(_type_ok(node, w) for w in wants):
            errors.append(f"{path}: expected {'|'.join(wants)}, got "
                          f"{type(node).__name__}")
            return
    enum = schema.get("enum")
    if enum is not None and node not in enum:
        errors.append(f"{path}: {node!r} not in {enum}")
    if isinstance(node, dict):
        for key in schema.get("required", []):
            if key not in node:
                errors.append(f"{path}: missing required key {key!r}")
        props = schema.get("properties", {})
        extra = schema.get("additionalProperties")
        for key, val in node.items():
            sub = props.get(key, extra if isinstance(extra, dict) else None)
            if sub is not None:
                _check(val, sub, f"{path}.{key}", errors)
    elif isinstance(node, list) and "items" in schema:
        for i, val in enumerate(node):
            _check(val, schema["items"], f"{path}[{i}]", errors)


def validate_record(record) -> list[str]:
    """Validate one event record; returns a list of errors (empty = ok)."""
    schema = load_schema()
    errors: list[str] = []
    _check(record, schema["common"], "$", errors)
    if errors:
        return errors
    sub = schema["events"].get(record["type"])
    if sub is None:   # enum check above already flagged unknown types
        return errors
    _check(record, sub, f"$[{record['type']}]", errors)
    return errors


def validate_stream(path: str) -> list[str]:
    """Validate every line of a JSONL event file."""
    errors: list[str] = []
    try:
        lines = Path(path).read_text().splitlines()
    except OSError as e:
        return [f"{path}: unreadable ({e})"]
    if not any(line.strip() for line in lines):
        return [f"{path}: empty event stream"]
    for lineno, line in enumerate(lines, 1):
        if not line.strip():
            continue
        try:
            record = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {lineno}: not JSON ({e})")
            continue
        errors.extend(f"line {lineno}: {e}"
                      for e in validate_record(record))
    return errors


__all__ = ["load_schema", "validate_record", "validate_stream"]
