"""Thread-safe metrics registry: counters, observations, compile records.

One registry rides on each :class:`repro.obs.Telemetry` session (ambient
instrumentation), and components with always-on accounting — the serving
engine's admission/batching counters — own a registry directly.
Everything is host-side Python; nothing here touches a device.
"""

from __future__ import annotations

import threading
from typing import Optional


class MetricsRegistry:
    """Counters (``inc``), observations (``observe``: count/total/min/max
    per key — phase wall-times use these), and per-signature compile
    records (:meth:`record_compile` / :meth:`compile_snapshot`)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, float] = {}
        self._observations: dict[str, dict] = {}
        self._compiles: list[dict] = []

    # -- counters ----------------------------------------------------------

    def inc(self, name: str, value: float = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + value

    def get(self, name: str, default: float = 0) -> float:
        with self._lock:
            return self._counters.get(name, default)

    # -- observations ------------------------------------------------------

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            o = self._observations.setdefault(
                name, {"count": 0, "total": 0.0, "min": None, "max": None})
            o["count"] += 1
            o["total"] += value
            o["min"] = value if o["min"] is None else min(o["min"], value)
            o["max"] = value if o["max"] is None else max(o["max"], value)

    # -- compile-cache records ---------------------------------------------

    def record_compile(self, fn: str, signature: str, trace_s: float,
                       compile_s: float, flops: Optional[float],
                       bytes_accessed: Optional[float],
                       fallback: bool = False) -> None:
        """One record per compile-cache *miss* (captured once per
        signature by :class:`repro.obs.InstrumentedJit`)."""
        with self._lock:
            self._compiles.append({
                "fn": fn, "signature": signature,
                "trace_s": trace_s, "compile_s": compile_s,
                "flops": flops, "bytes_accessed": bytes_accessed,
                "fallback": fallback,
            })

    # -- snapshots (all JSON-able plain dicts) -----------------------------

    def counters(self) -> dict:
        """Flat name -> number dict: counters plus flattened observation
        aggregates (``<name>.count`` / ``<name>.total_s``)."""
        with self._lock:
            out = dict(self._counters)
            for name, o in self._observations.items():
                out[f"{name}.count"] = o["count"]
                out[f"{name}.total_s"] = round(o["total"], 6)
            return out

    def observations(self) -> dict:
        with self._lock:
            return {k: dict(v) for k, v in self._observations.items()}

    def compile_snapshot(self) -> dict:
        """The ROADMAP's "surface hit rates" shape: hit/miss totals plus
        the per-signature compile records (trace/compile wall,
        cost_analysis FLOPs/bytes) — attached to ``SimResult.stats`` /
        ``DistResult.stats`` when a telemetry session is active."""
        with self._lock:
            return {
                "hits": int(self._counters.get("compile_cache.hits", 0)),
                "misses": int(self._counters.get("compile_cache.misses", 0)),
                "signatures": [dict(r) for r in self._compiles],
            }

    def snapshot(self) -> dict:
        return {"counters": self.counters(),
                "observations": self.observations(),
                "compile_cache": self.compile_snapshot()}


__all__ = ["MetricsRegistry"]
