"""The ambient telemetry session and the tracing-span API.

A session (:func:`telemetry` context manager) carries an optional
:class:`~repro.obs.events.EventSink` and a
:class:`~repro.obs.metrics.MetricsRegistry`.  Instrumented code asks
:func:`active` once and no-ops when there is no session — the whole
layer costs nothing (and adds zero device operations) unless the caller
opted in.

:class:`span` is the phase-tracing primitive: monotonic clock, nesting
(per-thread depth stacks, so concurrently supervised runs never corrupt
each other), and on exit one ``span`` event plus a
``phase.<name>`` observation in the registry.
"""

from __future__ import annotations

import contextlib
import time
from contextvars import ContextVar
from threading import local
from typing import Optional

from .events import _jsonable, coerce_sink
from .metrics import MetricsRegistry

_ACTIVE: ContextVar[Optional["Telemetry"]] = ContextVar(
    "repro_obs_active", default=None)


class Telemetry:
    """One telemetry session: sink + metrics + session-relative clock."""

    def __init__(self, sink=None, metrics: Optional[MetricsRegistry] = None,
                 validate: bool = False):
        self.sink = coerce_sink(sink)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.validate = validate
        self._t0 = time.monotonic()
        self._tls = local()    # per-thread span stacks

    def now(self) -> float:
        """Seconds since the session opened (monotonic)."""
        return time.monotonic() - self._t0

    def emit(self, type_: str, **fields) -> None:
        """Emit one event record (no-op without a sink; metrics still
        accumulate).  ``validate=True`` checks every record against
        ``schema.json`` before it is written — the tests' contract that
        the stream can never drift from the committed schema."""
        record = _jsonable({"t": round(self.now(), 6), "type": type_,
                            **fields})
        if self.validate:
            from .schema import validate_record
            errors = validate_record(record)
            if errors:
                raise ValueError(
                    f"invalid {type_!r} event: " + "; ".join(errors))
        if self.sink is not None:
            self.sink.emit(record)

    # -- span bookkeeping (per-thread) -------------------------------------

    def _stack(self) -> list:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = self._tls.stack = []
        return stack

    def close(self) -> None:
        if self.sink is not None:
            self.sink.close()


def active() -> Optional[Telemetry]:
    """The ambient session, or None — the one check every hook makes."""
    return _ACTIVE.get()


@contextlib.contextmanager
def telemetry(sink=None, *, metrics: Optional[MetricsRegistry] = None,
              validate: bool = False):
    """Open a telemetry session for the enclosed block.

        with obs.telemetry("run.jsonl"):
            simulate(c, cfg, t_steps, ...)

    ``sink`` is a path (JSONL file), a callable (one dict per event), an
    :class:`EventSink`, or None (metrics only).  The sink is closed —
    async writes joined, writer errors re-raised — when the block exits.
    """
    session = Telemetry(sink, metrics=metrics, validate=validate)
    token = _ACTIVE.set(session)
    try:
        yield session
    finally:
        _ACTIVE.reset(token)
        session.close()


class span:
    """Tracing span: ``with span("build", what="synapses"): ...``.

    No-op (two attribute checks, no clock read) without an active
    session.  On exit: ``wall_s`` is set on the span object, a ``span``
    event is emitted, and ``phase.<name>`` is observed in the registry.
    """

    __slots__ = ("name", "attrs", "wall_s", "_session", "_start", "_depth")

    def __init__(self, name: str, **attrs):
        self.name = name
        self.attrs = attrs
        self.wall_s: Optional[float] = None
        self._session: Optional[Telemetry] = None

    def __enter__(self):
        s = active()
        if s is not None:
            self._session = s
            stack = s._stack()
            self._depth = len(stack)
            stack.append(self.name)
            self._start = time.monotonic()
        return self

    def __exit__(self, exc_type, exc, tb):
        s = self._session
        if s is not None:
            self.wall_s = time.monotonic() - self._start
            s._stack().pop()
            s.metrics.observe(f"phase.{self.name}", self.wall_s)
            fields = {"name": self.name, "wall_s": round(self.wall_s, 6),
                      "depth": self._depth}
            if self.attrs:
                fields["attrs"] = self.attrs
            s.emit("span", **fields)
        return False


__all__ = ["Telemetry", "active", "span", "telemetry"]
