"""Event sinks: where telemetry records go.

A sink receives one JSON-able dict per event.  The only contract is
:meth:`EventSink.emit` / :meth:`EventSink.close`; :class:`JsonlSink`
streams records to a ``.jsonl`` file through a background writer thread
(the run loop never blocks on disk — same discipline as
:class:`repro.train.checkpoint.CheckpointHandle`: ``close()`` joins the
writer and re-raises anything it raised, so a write failure surfaces at
the supervision point instead of vanishing with a daemon thread).
"""

from __future__ import annotations

import json
import queue
import threading
from typing import Callable, Optional


def _jsonable(x):
    """Coerce numpy scalars / tuples into plain JSON types."""
    if isinstance(x, dict):
        return {str(k): _jsonable(v) for k, v in x.items()}
    if isinstance(x, (list, tuple)):
        return [_jsonable(v) for v in x]
    if isinstance(x, bool) or x is None or isinstance(x, (int, float, str)):
        return x
    item = getattr(x, "item", None)   # numpy / jax scalar
    if item is not None:
        try:
            return _jsonable(item())
        except (TypeError, ValueError):
            pass
    return str(x)


class EventSink:
    """Base sink: subclasses override :meth:`emit`; :meth:`close` is
    idempotent and must flush."""

    def emit(self, record: dict) -> None:
        raise NotImplementedError

    def close(self) -> None:
        pass


class CallbackSink(EventSink):
    """Deliver each record to a host callback (tests, live dashboards)."""

    def __init__(self, fn: Callable[[dict], None]):
        self._fn = fn

    def emit(self, record: dict) -> None:
        self._fn(record)


_CLOSE = object()


class JsonlSink(EventSink):
    """One JSON object per line, flushed by a background writer thread.

    ``emit`` enqueues and returns immediately (the chunk loop never
    waits on disk); ``close`` drains the queue, joins the writer, and
    re-raises any write-thread failure.  ``async_flush=False`` writes
    inline — deterministic ordering for tests.
    """

    def __init__(self, path: str, async_flush: bool = True):
        self.path = str(path)
        self._file = open(self.path, "w")
        self._error: Optional[BaseException] = None
        self._queue: Optional[queue.Queue] = None
        self._thread: Optional[threading.Thread] = None
        if async_flush:
            self._queue = queue.Queue()
            self._thread = threading.Thread(target=self._drain, daemon=True)
            self._thread.start()

    def _write(self, record: dict) -> None:
        self._file.write(json.dumps(_jsonable(record)) + "\n")
        self._file.flush()

    def _drain(self) -> None:
        while True:
            rec = self._queue.get()
            if rec is _CLOSE:
                return
            try:
                self._write(rec)
            except BaseException as e:  # noqa: BLE001 — re-raised in close
                self._error = e
                return

    def emit(self, record: dict) -> None:
        if self._error is not None:
            raise self._error
        if self._queue is not None:
            self._queue.put(record)
        else:
            self._write(record)

    def close(self) -> None:
        if self._thread is not None:
            self._queue.put(_CLOSE)
            self._thread.join()
            self._thread = None
        if not self._file.closed:
            self._file.close()
        if self._error is not None:
            err, self._error = self._error, None
            raise err


def coerce_sink(sink) -> Optional[EventSink]:
    """None | EventSink | path-like -> JsonlSink | callable -> CallbackSink."""
    if sink is None or isinstance(sink, EventSink):
        return sink
    if callable(sink):
        return CallbackSink(sink)
    return JsonlSink(sink)


__all__ = ["CallbackSink", "EventSink", "JsonlSink", "coerce_sink"]
