"""Per-signature compile-cache instrumentation for jitted entry points.

``simulate()`` is one jitted scan per (engine, stimulus, config, probes,
t_steps) signature, and the ROADMAP explicitly asks for the cache's hit
rates to be surfaced.  :class:`InstrumentedJit` wraps a ``jax.jit``-ed
function and, when a metrics registry is in reach (ambient telemetry
session, or one bound at construction — the serving engine's always-on
accounting), keys calls by their abstract signature exactly as jit does
(static argnum values + dynamic-leaf treedef/shape/dtype/weak-type) and:

* counts hits and misses (``compile_cache.hits`` / ``.misses``, plus
  per-function counters);
* on each miss, lowers and compiles ahead-of-time with the trace and
  compile phases timed separately (``span("compile")``), captures the
  compiled program's ``cost_analysis()`` FLOPs/bytes once per
  signature, emits a ``compile`` event, and caches the executable;
* dispatches through the cached executable — the same deterministic
  compilation the plain jit call would run, so results are
  bit-identical instrumented or not (pinned in tests/test_obs.py).

Without a registry the call passes straight through to the wrapped jit
function: zero overhead, zero behavior change.  If AOT lowering is
unsupported for some signature (e.g. an exotic transform), the wrapper
falls back to the plain call permanently for that signature and records
the miss with ``fallback=True`` — instrumentation must never take down
a run.
"""

from __future__ import annotations

import hashlib
import time
from typing import Optional

import numpy as np

from .metrics import MetricsRegistry
from .trace import active, span

#: sentinel: this signature routes through the plain jit call forever
_PLAIN = object()


def _cost_analysis(compiled) -> tuple[Optional[float], Optional[float]]:
    """(flops, bytes accessed) from the compiled program, when the
    backend reports them (CPU does; some backends return nothing)."""
    try:
        cost = compiled.cost_analysis()
    except Exception:  # noqa: BLE001 — optional metadata only
        return None, None
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    if not isinstance(cost, dict):
        return None, None
    flops = cost.get("flops")
    nbytes = cost.get("bytes accessed")
    return (float(flops) if flops is not None else None,
            float(nbytes) if nbytes is not None else None)


class InstrumentedJit:
    """Wrap a ``jax.jit``-ed function with compile-cache metrics.

    ``static_argnums`` must match the wrapped jit's (the wrapper keys
    and drops them exactly as jit does).  ``registry`` binds always-on
    accounting; otherwise the ambient session's registry is used when
    one is active.
    """

    def __init__(self, fn, name: str, static_argnums=(),
                 registry: Optional[MetricsRegistry] = None):
        self.fn = fn
        self.name = name
        self.registry = registry
        self._static = frozenset(static_argnums)
        self._cache: dict = {}

    # -- signature keying (mirrors jit's cache key) ------------------------

    def _signature(self, args) -> tuple:
        import jax
        parts = []
        for i, a in enumerate(args):
            if i in self._static:
                parts.append(("s", a))
            else:
                leaves, treedef = jax.tree_util.tree_flatten(a)
                parts.append(("d", treedef, tuple(
                    (np.shape(leaf),
                     str(getattr(leaf, "dtype", type(leaf).__name__)),
                     bool(getattr(leaf, "weak_type", False)))
                    for leaf in leaves)))
        return tuple(parts)

    @staticmethod
    def _sig_id(key) -> str:
        return hashlib.sha256(repr(key).encode()).hexdigest()[:12]

    # -- dispatch ----------------------------------------------------------

    def _compile(self, tele, reg: MetricsRegistry, key, args):
        sig = self._sig_id(key)
        try:
            with span("compile", fn=self.name, signature=sig):
                t0 = time.monotonic()
                lowered = self.fn.lower(*args)
                t1 = time.monotonic()
                compiled = lowered.compile()
                t2 = time.monotonic()
            flops, nbytes = _cost_analysis(compiled)
            trace_s, compile_s = t1 - t0, t2 - t1
            entry = compiled
        except Exception:  # noqa: BLE001 — fall back to the plain call
            flops = nbytes = None
            trace_s = compile_s = 0.0
            entry = _PLAIN
        self._cache[key] = entry
        reg.record_compile(self.name, sig, trace_s, compile_s, flops,
                           nbytes, fallback=entry is _PLAIN)
        if tele is not None:
            tele.emit("compile", fn=self.name, signature=sig,
                      trace_s=round(trace_s, 6),
                      compile_s=round(compile_s, 6), flops=flops,
                      bytes_accessed=nbytes, fallback=entry is _PLAIN)
        return entry

    def __call__(self, *args):
        tele = active()
        reg = self.registry if self.registry is not None else (
            tele.metrics if tele is not None else None)
        if reg is None:
            return self.fn(*args)
        key = self._signature(args)
        entry = self._cache.get(key)
        if entry is None:
            reg.inc("compile_cache.misses")
            reg.inc(f"compile_cache.{self.name}.misses")
            entry = self._compile(tele, reg, key, args)
        else:
            reg.inc("compile_cache.hits")
            reg.inc(f"compile_cache.{self.name}.hits")
        if entry is _PLAIN:
            return self.fn(*args)
        return entry(*(a for i, a in enumerate(args)
                       if i not in self._static))


__all__ = ["InstrumentedJit"]
