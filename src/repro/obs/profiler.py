"""``jax.profiler.trace`` gating for the launcher's ``--profile DIR``.

The telemetry layer answers "where did wall-clock go" at phase/chunk
granularity; when that points at the compiled program itself, the next
level down is the XLA profiler.  :func:`profile_trace` wraps a block in
``jax.profiler.trace(dir)`` (TensorBoard-loadable trace files) and is a
no-op when ``directory`` is falsy, so call sites can pass the CLI flag
straight through.
"""

from __future__ import annotations

import contextlib


@contextlib.contextmanager
def profile_trace(directory):
    if not directory:
        yield
        return
    import jax
    with jax.profiler.trace(str(directory)):
        yield


__all__ = ["profile_trace"]
