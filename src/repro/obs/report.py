"""Run-report CLI: summarize a telemetry JSONL event stream.

    python -m repro.obs.report run.jsonl

Renders, from any stream the schema accepts (live tail of a running
simulation or a finished run):

* the run header (kind, engine/scheme, n, steps, total wall);
* a phase table from the ``span`` events — where wall-clock went;
* chunk throughput (overall steps/sec, per-chunk min/median/max);
* the final cumulative counters (spikes, drops, health sentinels,
  tile-skip stats — whatever the carry carried);
* the compile-cache table (per-signature trace/compile wall,
  cost_analysis FLOPs/bytes, hit/miss totals);
* resilience events (health breaches, checkpoints, restarts,
  escalations), when any occurred;
* serving traffic (``serve_*`` events): terminal-status counts,
  completed-request latency p50/p99, shed/retry/quarantine incidents.

Everything is plain text, zero dependencies; exit code 1 when the
stream contains no events.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path


def load_events(path: str) -> list[dict]:
    events = []
    for line in Path(path).read_text().splitlines():
        if line.strip():
            events.append(json.loads(line))
    return events


def _by_type(events, type_: str) -> list[dict]:
    return [e for e in events if e.get("type") == type_]


def _fmt_s(v) -> str:
    return "-" if v is None else f"{v:.3f}s"


def _table(rows: list[list[str]], header: list[str]) -> str:
    widths = [max(len(str(r[i])) for r in [header] + rows)
              for i in range(len(header))]
    def line(r):
        return "  ".join(str(c).ljust(w) for c, w in zip(r, widths)).rstrip()
    return "\n".join([line(header), line(["-" * w for w in widths])]
                     + [line(r) for r in rows])


def _median(xs: list[float]) -> float:
    xs = sorted(xs)
    m = len(xs) // 2
    return xs[m] if len(xs) % 2 else 0.5 * (xs[m - 1] + xs[m])


def summarize(events: list[dict]) -> str:
    out: list[str] = []

    # -- run header --------------------------------------------------------
    starts, ends = _by_type(events, "run_start"), _by_type(events, "run_end")
    for s in starts:
        what = s.get("engine") or s.get("scheme") or "?"
        out.append(f"run: {s.get('kind', '?')} ({what}) n={s.get('n', '?')} "
                   f"t_steps={s.get('t_steps', '?')}"
                   + (f" chunk_steps={s['chunk_steps']}"
                      if s.get("chunk_steps") else "")
                   + (" [fixed-point]" if s.get("fixed_point") else ""))
    for e in ends:
        out.append(f"completed: {e.get('steps', '?')} steps in "
                   f"{_fmt_s(e.get('wall_s'))}")
    if not starts and not ends:
        out.append(f"(no run_start/run_end — partial stream of "
                   f"{len(events)} events)")

    # -- phases ------------------------------------------------------------
    spans: dict[str, dict] = {}
    for e in _by_type(events, "span"):
        p = spans.setdefault(e["name"], {"count": 0, "total": 0.0,
                                         "max": 0.0})
        p["count"] += 1
        p["total"] += e["wall_s"]
        p["max"] = max(p["max"], e["wall_s"])
    if spans:
        out.append("")
        out.append("phases (spans):")
        rows = [[name, p["count"], f"{p['total']:.3f}s",
                 f"{p['total'] / p['count']:.4f}s", f"{p['max']:.4f}s"]
                for name, p in sorted(spans.items(),
                                      key=lambda kv: -kv[1]["total"])]
        out.append(_table(rows, ["phase", "count", "total", "mean", "max"]))

    # -- chunk throughput --------------------------------------------------
    chunks = _by_type(events, "chunk")
    if chunks:
        steps = sum(c["steps"] for c in chunks)
        wall = sum(c["wall_s"] for c in chunks)
        rates = [c["steps_per_s"] for c in chunks]
        out.append("")
        out.append(f"throughput: {steps} steps / {wall:.3f}s over "
                   f"{len(chunks)} chunk(s) = "
                   f"{steps / wall if wall else float('inf'):.1f} steps/sec "
                   f"(per-chunk min {min(rates):.1f} / median "
                   f"{_median(rates):.1f} / max {max(rates):.1f})")
        final = chunks[-1].get("counters", {})
        if final:
            out.append("counters (cumulative at last chunk): " + "  ".join(
                f"{k}={v}" for k, v in sorted(final.items())))
    elif ends and ends[-1].get("counters"):
        out.append("counters: " + "  ".join(
            f"{k}={v}" for k, v in sorted(ends[-1]["counters"].items())))

    # -- compile cache -----------------------------------------------------
    compiles = _by_type(events, "compile")
    metrics = ends[-1].get("metrics", {}) if ends else {}
    hits = metrics.get("compile_cache.hits")
    misses = metrics.get("compile_cache.misses")
    if compiles or hits is not None or misses is not None:
        out.append("")
        hm = (f" (hits={int(hits or 0)} misses={int(misses or 0)})"
              if hits is not None or misses is not None else "")
        out.append(f"compile cache: {len(compiles)} compile(s){hm}")
        if compiles:
            rows = [[c["fn"], c["signature"][:12],
                     f"{c['trace_s']:.3f}s", f"{c['compile_s']:.3f}s",
                     "-" if c.get("flops") is None
                     else f"{c['flops']:.3g}",
                     "-" if c.get("bytes_accessed") is None
                     else f"{c['bytes_accessed']:.3g}",
                     "fallback" if c.get("fallback") else ""]
                    for c in compiles]
            out.append(_table(rows, ["fn", "signature", "trace", "compile",
                                     "flops", "bytes", ""]))

    # -- serving -----------------------------------------------------------
    req_ends = _by_type(events, "serve_request_end")
    if req_ends:
        out.append("")
        statuses: dict[str, int] = {}
        for e in req_ends:
            statuses[e["status"]] = statuses.get(e["status"], 0) + 1
        out.append("serving: " + "  ".join(
            f"{k}={v}" for k, v in sorted(statuses.items())))
        lat = sorted(e["wall_s"] for e in req_ends
                     if e["status"] == "completed")
        if lat:
            p = lambda q: lat[min(len(lat) - 1,    # noqa: E731
                                  int(q * (len(lat) - 1) + 0.5))]
            out.append(f"request latency: p50={p(0.5):.3f}s "
                       f"p99={p(0.99):.3f}s over {len(lat)} completed")
        reasons: dict[str, int] = {}
        for e in req_ends:
            if e.get("reason"):
                reasons[e["reason"]] = reasons.get(e["reason"], 0) + 1
        if reasons:
            out.append("terminal reasons: " + "  ".join(
                f"{k}={v}" for k, v in sorted(reasons.items())))
        serve_incidents = [e for e in events if e.get("type") in
                           ("serve_shed", "serve_retry", "serve_quarantine",
                            "serve_deadline", "serve_degrade")]
        for e in serve_incidents:
            kind = e["type"].removeprefix("serve_")
            who = (f"rid={e['rid']}" if "rid" in e
                   else f"rids={e.get('rids')}")
            detail = e.get("reason") or e.get("error") or e.get("what") or ""
            out.append(f"  t={e['t']:.3f}s {kind} {who}"
                       + (f" ({detail})" if detail else "")
                       + (f" backoff={e['backoff_s']:.3f}s"
                          if "backoff_s" in e else ""))

    # -- resilience events -------------------------------------------------
    ckpts = _by_type(events, "checkpoint")
    if ckpts:
        out.append("")
        out.append(f"checkpoints: {len(ckpts)} "
                   f"(steps {', '.join(str(c['step']) for c in ckpts)})")
    incidents = [e for e in events
                 if e.get("type") in ("health", "restart", "escalation")]
    if incidents:
        out.append("")
        out.append("incidents:")
        for e in incidents:
            kind = e["type"]
            if kind == "health":
                out.append(f"  t={e['t']:.3f}s health breach "
                           f"{e['kind']}={e['value']} at step {e['step']} "
                           f"(threshold {e.get('threshold')})")
            else:
                out.append(f"  t={e['t']:.3f}s {kind} #{e['attempt']} -> "
                           f"resume from {e.get('resume_step')}"
                           + (f" ({e['error']})" if e.get("error") else ""))
    return "\n".join(out)


def main(argv: list[str]) -> int:
    if len(argv) != 1:
        sys.exit("usage: python -m repro.obs.report run.jsonl")
    events = load_events(argv[0])
    if not events:
        print(f"{argv[0]}: no events")
        return 1
    print(summarize(events))
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
