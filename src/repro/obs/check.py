"""Validate telemetry JSONL streams against the committed schema.

    python -m repro.obs.check run.jsonl [more.jsonl ...]

Exit code is the number of invalid files (``benchmarks/check_schema.py``
convention) — CI gates the telemetry smoke on it.
"""

from __future__ import annotations

import sys

from .schema import validate_stream


def main(argv: list[str]) -> int:
    if not argv:
        sys.exit("usage: python -m repro.obs.check run.jsonl [...]")
    bad = 0
    for path in argv:
        errors = validate_stream(path)
        if errors:
            bad += 1
            print(f"FAIL {path}")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"ok   {path}")
    return bad


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
