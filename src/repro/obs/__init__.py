"""Runtime telemetry for the simulator (docs/observability.md).

The paper's validation is statistical and its headline claim is
throughput — so a run must be able to answer, *while it runs*, where
wall-clock goes (build vs trace vs compile vs execute), whether the
per-signature compiled-program cache is hitting, and how a long chunked
simulation is progressing.  This package is that answer, and it is
strictly off-path: **zero extra device operations when no telemetry
session is active** (every hook checks :func:`active` once and
no-ops), and with telemetry on, all instrumentation happens host-side
at chunk/run granularity — O(1) per chunk, never O(n) per step — so
raster/state results are bit-identical with telemetry on or off
(pinned in tests/test_obs.py).

Pieces:

* :func:`telemetry` / :func:`active` / :class:`Telemetry` — the ambient
  session: an optional :class:`EventSink` (JSONL file, callback) plus a
  :class:`MetricsRegistry`.
* :class:`span` — nested, thread-safe, monotonic-clock tracing spans
  (``span("build")`` / ``span("compile")`` / ``span("chunk")``) wired
  through ``simulate``, ``simulate_distributed``, ``run_resilient`` and
  the host-side builds.
* :class:`InstrumentedJit` — per-signature compile-cache metrics
  (hit/miss counters, trace+compile wall time, ``cost_analysis()``
  FLOPs/bytes) around the jitted scan entry points.
* :class:`JsonlSink` — async-flushed streamed events, one record per
  chunk boundary, validated by ``schema.json``
  (``python -m repro.obs.check run.jsonl``).
* ``python -m repro.obs.report run.jsonl`` — phase/throughput/health
  summary of any event stream.
* :func:`profile_trace` — ``jax.profiler.trace`` gating for the
  launcher's ``--profile DIR``.
"""

from .events import CallbackSink, EventSink, JsonlSink
from .jit import InstrumentedJit
from .metrics import MetricsRegistry
from .profiler import profile_trace
from .schema import validate_record
from .trace import Telemetry, active, span, telemetry

__all__ = [
    "CallbackSink",
    "EventSink",
    "InstrumentedJit",
    "JsonlSink",
    "MetricsRegistry",
    "Telemetry",
    "active",
    "profile_trace",
    "span",
    "telemetry",
    "validate_record",
]
