"""Simulation-serving driver: a mixed fault-injected workload through
:class:`repro.serving.SimServer`, with every terminal state accounted.

    PYTHONPATH=src python -m repro.launch.serve --n 400 --requests 8 \
        --inject-fault --poison --telemetry /tmp/serve.jsonl

Builds a synthetic connectome, submits a workload that mixes scenarios,
seeds, priorities and probe specs (plus, on request, one crash-injected
and one poisoned request), drains it, and prints one line per request
with its terminal status.  Exits non-zero if any submitted request
failed to reach a terminal state (completed / rejected-with-reason /
quarantined) or if a healthy request came back without a result — the
CI serving smoke's contract.  See ``docs/serving.md``.
"""

from __future__ import annotations

import argparse
import contextlib
import sys
import time

import numpy as np

from repro import obs
from repro.core import SimConfig, synthetic_flywire_cached
from repro.core.exchange import FaultSpec, configure_faulty
from repro.core.health import BackoffPolicy, HealthConfig
from repro.exp import ProbeSpec
from repro.serving import TERMINAL, SimRequest, SimServeConfig, SimServer


def build_workload(requests: int, t_steps: int, inject_fault: bool,
                   poison: bool) -> list[SimRequest]:
    """A mixed workload: two scenario tiers (batchable within each),
    alternating probe specs and priorities, distinct seeds — plus one
    crash-injected and one poisoned request when asked."""
    reqs: list[SimRequest] = []
    for i in range(requests):
        scenario = "sugar_feeding" if i % 2 == 0 else "step_response"
        probes = (ProbeSpec(pop_rate=True) if i % 3 else
                  ProbeSpec(pop_rate=True, drops=True))
        reqs.append(SimRequest(scenario=scenario, t_steps=t_steps, seed=i,
                               probes=probes, priority=i % 2))
    if inject_fault and reqs:
        # host-side crash at the second chunk boundary, once, via the
        # faulty exchange wrapper's supervision hook (docs/resilience.md)
        spec = FaultSpec(partition=0, fail_at=(t_steps // 3,))
        reqs[0].fault_hook = configure_faulty("event", spec).host_supervise
    if poison:
        reqs.append(SimRequest(scenario="step_response", t_steps=t_steps,
                               seed=len(reqs),
                               params={"amp": float("nan")}))
    return reqs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=400)
    ap.add_argument("--synapses", type=int, default=8_000)
    ap.add_argument("--t-ms", type=float, default=10.0)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--engine", default="csr")
    ap.add_argument("--fixed-point", action="store_true")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-queue", type=int, default=32)
    ap.add_argument("--chunk-steps", type=int, default=25)
    ap.add_argument("--inject-fault", action="store_true",
                    help="give one request a host-side crash hook "
                         "(exercises retry-with-backoff)")
    ap.add_argument("--poison", action="store_true",
                    help="add one NaN-stimulus request (exercises "
                         "per-lane health attribution and quarantine)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="per-request wall-clock deadline")
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="stream serve_* JSONL events to PATH")
    args = ap.parse_args(argv)

    c = synthetic_flywire_cached(n=args.n, seed=0,
                                 target_synapses=args.synapses)
    cfg = SimConfig(engine=args.engine, fixed_point=args.fixed_point,
                    health=HealthConfig())
    t_steps = int(round(args.t_ms / cfg.params.dt))
    serve = SimServeConfig(
        max_queue=args.max_queue, max_batch=args.max_batch,
        chunk_steps=args.chunk_steps,
        default_deadline_s=args.deadline_s,
        backoff=BackoffPolicy(base_s=0.05, cap_s=2.0))
    reqs = build_workload(args.requests, t_steps, args.inject_fault,
                          args.poison)
    print(f"[serve] n={c.n} engine={cfg.engine} t_steps={t_steps} "
          f"requests={len(reqs)} (fault={args.inject_fault} "
          f"poison={args.poison})")

    with contextlib.ExitStack() as stack:
        if args.telemetry:
            stack.enter_context(obs.telemetry(args.telemetry))
        server = SimServer(c, cfg, serve)
        t0 = time.monotonic()
        done = server.run(reqs)
        wall = time.monotonic() - t0

    bad = 0
    for r in done:
        spikes = (int(np.asarray(r.result.counts).sum())
                  if r.result is not None else "-")
        print(f"[serve] rid={r.rid} {r.scenario}(seed={r.seed}) -> "
              f"{r.status}"
              + (f" ({r.reason})" if r.reason else "")
              + (f" [{type(r.error).__name__}]" if r.error else "")
              + f" spikes={spikes} wall={r.latency_s:.2f}s")
        if not r.terminal:
            print(f"[serve] ERROR rid={r.rid} non-terminal "
                  f"status {r.status!r}", file=sys.stderr)
            bad += 1
        if r.status == "completed" and r.result is None:
            print(f"[serve] ERROR rid={r.rid} completed without a result",
                  file=sys.stderr)
            bad += 1
    missing = set(id(r) for r in reqs) - set(id(r) for r in done)
    if missing:
        print(f"[serve] ERROR {len(missing)} submitted request(s) never "
              f"came back", file=sys.stderr)
        bad += len(missing)

    stats = server.stats()
    terminal_total = sum(stats[k] for k in TERMINAL)
    print(f"[serve] {stats['completed']} completed / "
          f"{stats['rejected']} rejected / "
          f"{stats['quarantined']} quarantined of {stats['submitted']} "
          f"in {wall:.2f}s ({stats['retries']} retries, "
          f"{stats['escalations']} escalations, {stats['shed']} shed)")
    if terminal_total != stats["submitted"]:
        print(f"[serve] ERROR terminal states ({terminal_total}) != "
              f"submitted ({stats['submitted']})", file=sys.stderr)
        bad += 1
    if stats["latency_p50_s"] is not None:
        print(f"[serve] request latency p50={stats['latency_p50_s']:.3f}s "
              f"p99={stats['latency_p99_s']:.3f}s")
    if args.telemetry:
        print(f"[serve] telemetry stream: {args.telemetry} "
              f"(python -m repro.obs.report {args.telemetry})")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
