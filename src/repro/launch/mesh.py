"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — required because the dry-run
overrides the device count via XLA_FLAGS before first jax init, while
tests and benches must keep seeing 1 device.
"""

from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # AxisType landed after jax 0.4; older versions imply Auto everywhere
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - version-dependent
    AxisType = None


def _axis_kw(n_axes: int) -> dict:
    if AxisType is None:
        return {}
    return {"axis_types": (AxisType.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.  Multi-pod: a leading
    pure-DP "pod" axis (2, 16, 16) = 512 chips across the DCN boundary."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_host_mesh(data: int = 2, model: int = 2, pod: int | None = None):
    """Small mesh over however many host devices exist (tests)."""
    if pod:
        shape, axes = (pod, data, model), ("pod", "data", "model")
    else:
        shape, axes = (data, model), ("data", "model")
    n = int(np.prod(shape))
    if len(jax.devices()) < n:
        raise ValueError(f"need {n} devices, have {len(jax.devices())}")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_flat_mesh(n_cores: int, name: str = "cores"):
    """1-D mesh used by the distributed SNN simulator (one neuron partition
    per device)."""
    return jax.make_mesh((n_cores,), (name,), **_axis_kw(1))
