"""Shared builders: (arch x shape-cell x mesh) -> jit-ready step function
with abstract inputs + shardings.  Used by dryrun.py (512-device lower +
compile), by tests (small host meshes), and by the perf loop.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import SHAPES, cache_specs, cell_supported, input_specs
from repro.models import (ModelConfig, abstract_params, decode_step,
                          param_axes, prefill)
from repro.optim import AdamW, cosine_schedule
from repro.parallel import act
from repro.parallel.sharding import (DEFAULT_RULES, ShardingRules,
                                     batch_spec, data_axis_size,
                                     make_param_shardings, solve_rules)
from repro.train import make_train_step
from repro.train.train_step import TrainState, init_train_state


class BuiltStep(NamedTuple):
    fn: Any                 # python callable, jit-able
    in_avals: tuple         # abstract args
    in_shardings: tuple
    donate_argnums: tuple
    kind: str
    meta: dict
    policy: dict            # activation sharding policy (repro.parallel.act)
    out_shardings: Any = None


def _act_policy(mesh: Mesh, cfg, cell: str) -> dict:
    """Activation-sharding policy for this (cfg, cell, mesh)."""
    sizes = _mesh_sizes(mesh)
    dp = _dp_axes(mesh)
    n_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    model_n = sizes.get("model", 1)
    B = SHAPES[cell]["batch"]
    bax = dp if (dp and B % n_dp == 0) else None
    policy = {"residual": P(bax, None, None),
              "moe_buf": P(bax, None, None, None)}
    if model_n > 1 and cfg.vocab % model_n == 0:
        policy["logits"] = P(bax, None, "model")
    return policy


def _mesh_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _logits_sharding(mesh, cfg, B):
    sizes = _mesh_sizes(mesh)
    dp = _dp_axes(mesh)
    n_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    model_n = sizes.get("model", 1)
    bax = dp if (dp and B % n_dp == 0) else None
    vax = "model" if (model_n > 1 and cfg.vocab % model_n == 0) else None
    return NamedSharding(mesh, P(bax, vax))


def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _shard_batch_tree(mesh, tree):
    """Leading-dim data-parallel sharding for a batch pytree."""
    dp = _dp_axes(mesh)
    n_dp = int(np.prod([_mesh_sizes(mesh)[a] for a in dp])) if dp else 1

    def spec(x):
        if x.ndim == 0 or (dp and x.shape[0] % n_dp != 0) or not dp:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1))))

    return jax.tree.map(spec, tree)


# decode-cache sharding strategy: "auto" (heads if divisible, else
# sequence) or "heads_padded" (always the kv-heads dim — GSPMD pads
# non-divisible heads; trades idle compute/duplicated cache rows for
# fully cache-local scatter + attention, the §Perf decode iteration)
CACHE_MODE = "auto"


def _cache_sharding(mesh, aval, cfg):
    """KV/state cache sharding.

    Normal case: batch dim over the data axes, then the first
    model-divisible dim after it (heads if divisible, else sequence) over
    "model".  Unshardable batch (long_500k B=1): fold data+model onto the
    longest divisible dim (the 524288-entry sequence) so the cache always
    distributes over the full mesh — a replicated 500k cache would be
    ~200 GiB/device."""
    sizes = _mesh_sizes(mesh)
    model_n = sizes.get("model", 1)
    dp = _dp_axes(mesh)
    n_dp = int(np.prod([sizes[a] for a in dp])) if dp else 1
    shape = aval.shape
    spec = [None] * len(shape)
    # batch dim: dim 0 (tail caches) or dim 1 (scan-stacked caches)
    batch_dim = None
    for i in (1, 0):
        if i < len(shape) and dp and shape[i] % n_dp == 0 and shape[i] >= n_dp:
            batch_dim = i
            break
    if batch_dim is not None:
        spec[batch_dim] = dp
        if model_n > 1:
            if (CACHE_MODE == "heads_padded" and len(shape) >= batch_dim + 3
                    and shape[batch_dim + 1] > 1):
                spec[batch_dim + 1] = "model"   # kv-heads, padded if uneven
                return NamedSharding(mesh, P(*spec))
            for j in range(batch_dim + 1, len(shape)):
                if shape[j] % model_n == 0 and shape[j] >= model_n:
                    spec[j] = "model"
                    break
        return NamedSharding(mesh, P(*spec))
    # batch unshardable: put all mesh axes on the longest divisible dim
    total = n_dp * model_n
    dims = sorted(range(1, len(shape)), key=lambda j: -shape[j])
    for j in dims:
        if shape[j] % total == 0 and shape[j] >= total:
            spec[j] = dp + ("model",) if dp else "model"
            return NamedSharding(mesh, P(*spec))
    for j in dims:
        if model_n > 1 and shape[j] % model_n == 0 and shape[j] >= model_n:
            spec[j] = "model"
            break
    return NamedSharding(mesh, P(*spec))


def build_step(arch: str, cell: str, mesh: Mesh,
               rules: ShardingRules = DEFAULT_RULES,
               microbatches: int = 0, smoke: bool = False,
               overrides: dict | None = None) -> BuiltStep:
    """Build the lower-ready step for one (arch, cell, mesh) combination."""
    cfg = get_config(arch, smoke=smoke)
    spec = SHAPES[cell]
    kind = spec["kind"]
    ok, why = cell_supported(cfg, cell)
    if not ok:
        raise ValueError(f"{arch} x {cell} unsupported: {why}")

    if not smoke:
        # production dtypes: bf16 compute everywhere; serving weights bf16
        over = {"compute_dtype": jnp.bfloat16}
        if kind in ("prefill", "decode"):
            over["param_dtype"] = jnp.bfloat16
            over["remat"] = False
        if overrides:
            over.update(overrides)
        cfg = dataclasses.replace(cfg, **over)
    elif overrides:
        cfg = dataclasses.replace(cfg, **overrides)

    aparams = abstract_params(cfg)
    axes = param_axes(cfg)
    param_sh, fallbacks = make_param_shardings(mesh, axes, aparams, rules)
    meta = {"arch": arch, "cell": cell, "kind": kind,
            "mesh": dict(zip(mesh.axis_names, mesh.devices.shape)),
            "sharding_fallbacks": [f"{n}:{d}%{a}={s}"
                                   for (n, d, a, s) in fallbacks]}
    policy = _act_policy(mesh, cfg, cell)

    ins = input_specs(cfg, cell, smoke_scale=smoke)

    if kind == "train":
        if microbatches <= 0:
            microbatches = default_microbatches(arch, cell)
        opt = AdamW(lr=cosine_schedule(3e-4, 2000, 100_000),
                    state_dtype=optimizer_state_dtype(arch))
        step = make_train_step(cfg, opt, microbatches=microbatches)
        astate = jax.eval_shape(
            lambda ap: init_train_state(ap, opt), aparams)
        state_sh = TrainState(
            params=param_sh,
            opt=type(astate.opt)(step=NamedSharding(mesh, P()),
                                 m=param_sh, v=param_sh),
            residual=None)
        batch_sh = _shard_batch_tree(mesh, ins["batch"])
        meta["microbatches"] = microbatches
        meta["opt_state_dtype"] = jnp.dtype(optimizer_state_dtype(arch)).name
        return BuiltStep(fn=step, in_avals=(astate, ins["batch"]),
                         in_shardings=(state_sh, batch_sh),
                         donate_argnums=(0,), kind=kind, meta=meta,
                         policy=policy)

    if kind == "prefill":
        B = ins["batch"]["tokens"].shape[0]
        S = spec["seq"]
        if smoke:
            S = max(32, S // 512)
        max_len = cfg.dec_max if cfg.is_encdec else S
        fn = functools.partial(_prefill_fn, cfg=cfg, max_len=max_len)
        batch_sh = _shard_batch_tree(mesh, ins["batch"])
        # explicit output shardings: without them the (huge) returned kv
        # cache can come back badly distributed (observed: 93 GiB/device
        # for grok prefill_32k with unspecified outputs)
        with act.policy(policy), mesh:
            out_aval = jax.eval_shape(fn, aparams, ins["batch"])
        out_sh = (_logits_sharding(mesh, cfg, B),
                  jax.tree.map(lambda a: _cache_sharding(mesh, a, cfg),
                               out_aval[1]))
        return BuiltStep(fn=fn, in_avals=(aparams, ins["batch"]),
                         in_shardings=(param_sh, batch_sh),
                         donate_argnums=(), kind=kind, meta=meta,
                         policy=policy, out_shardings=out_sh)

    # decode
    dtype = cfg.param_dtype if not smoke else jnp.float32
    acache = cache_specs(cfg, cell, dtype=dtype, smoke_scale=smoke)
    cache_sh = jax.tree.map(lambda a: _cache_sharding(mesh, a, cfg), acache)
    tok_sh = _shard_batch_tree(mesh, {"t": ins["tokens"]})["t"]
    pos_sh = NamedSharding(mesh, P())
    fn = functools.partial(_decode_fn, cfg=cfg)
    B = ins["tokens"].shape[0]
    out_sh = (_logits_sharding(mesh, cfg, B), cache_sh)
    return BuiltStep(
        fn=fn,
        in_avals=(aparams, acache, ins["tokens"], ins["pos"]),
        in_shardings=(param_sh, cache_sh, tok_sh, pos_sh),
        donate_argnums=(1,), kind=kind, meta=meta, policy=policy,
        out_shardings=out_sh)


def _prefill_fn(params, batch, *, cfg, max_len):
    return prefill(params, batch, cfg, max_len)


def _decode_fn(params, cache, tokens, pos, *, cfg):
    return decode_step(params, cache, tokens, pos, cfg)


def default_microbatches(arch: str, cell: str) -> int:
    """Activation-memory heuristic (derivation in EXPERIMENTS.md §Dry-run):
    scan residuals per device ~ L x B_loc/M x S x d_model x 2B must sit
    well under HBM after params+optimizer."""
    big = {"grok-1-314b": 16, "llava-next-34b": 16, "command-r-35b": 16,
           "llama4-scout-17b-a16e": 8, "qwen2.5-14b": 8, "gemma3-12b": 8,
           "phi3-medium-14b": 8}
    return big.get(arch, 4)


def optimizer_state_dtype(arch: str):
    """grok-1's 314B at 12B/param would alone exceed v5e HBM on 256 chips
    (14.7 GiB/device); bf16 m/v halves it (documented trade-off)."""
    return jnp.bfloat16 if arch == "grok-1-314b" else jnp.float32


def analytic_bytes(built: BuiltStep) -> dict:
    """Exact per-device resident bytes by input category (independent of
    the CPU backend's bf16->f32 legalization, which inflates
    memory_analysis temp on this container; see EXPERIMENTS.md)."""
    import math

    def tree_bytes(aval_tree, sh_tree):
        total = 0
        avals = jax.tree.leaves(aval_tree)
        shs = jax.tree.leaves(sh_tree,
                              is_leaf=lambda x: isinstance(x, NamedSharding))
        for a, sh in zip(avals, shs):
            shard = sh.shard_shape(a.shape) if isinstance(
                sh, NamedSharding) else a.shape
            total += math.prod(shard) * jnp.dtype(a.dtype).itemsize
        return total

    cats = {}
    if built.kind == "train":
        state, batch = built.in_avals
        state_sh, batch_sh = built.in_shardings
        cats["params"] = tree_bytes(state.params, state_sh.params)
        cats["opt_state"] = tree_bytes(state.opt, state_sh.opt)
        cats["batch"] = tree_bytes(batch, batch_sh)
    elif built.kind == "prefill":
        params, batch = built.in_avals
        p_sh, b_sh = built.in_shardings
        cats["params"] = tree_bytes(params, p_sh)
        cats["batch"] = tree_bytes(batch, b_sh)
        cats["cache_out"] = tree_bytes(
            jax.eval_shape(built.fn, *built.in_avals)[1],
            built.out_shardings[1])
    else:
        params, cache, toks, pos = built.in_avals
        p_sh, c_sh, *_ = built.in_shardings
        cats["params"] = tree_bytes(params, p_sh)
        cats["cache"] = tree_bytes(cache, c_sh)
    cats["total"] = sum(cats.values())
    return cats


def lower_and_compile(built: BuiltStep, mesh: Mesh):
    kw = {}
    if built.out_shardings is not None:
        kw["out_shardings"] = built.out_shardings
    with act.policy(built.policy), mesh:
        jitted = jax.jit(built.fn, in_shardings=built.in_shardings,
                         donate_argnums=built.donate_argnums, **kw)
        lowered = jitted.lower(*built.in_avals)
        compiled = lowered.compile()
    return lowered, compiled
