"""Optimized-HLO analysis: trip-count-aware FLOPs / HBM-bytes / collective
accounting for the roofline.

XLA's built-in ``compiled.cost_analysis()`` counts while-loop *bodies
once*, independent of trip count (verified empirically: a scan of 1 vs 64
matmuls reports identical flops) — useless for scanned-layer programs.
This module parses the post-optimization HLO text into its computation
graph, extracts each while loop's trip count from its condition region's
induction bound, and accumulates per-computation costs multiplied by the
product of enclosing trip counts:

  * flops       — dot ops (2 x prod(result) x contracted size), including
                  dots inside fusion subcomputations.  MXU work; large
                  elementwise (VPU) work is visible in `bytes` instead.
  * bytes       — operand + result bytes of every top-level instruction in
                  non-fused computations (post-fusion, this approximates
                  HBM traffic: fusion internals stay in registers/VMEM).
  * link bytes  — collective ops converted to per-device link traffic with
                  ring-algorithm factors:
                    all-gather          (g-1)/g x result
                    reduce-scatter      (g-1)   x result   (operand = g x result)
                    all-reduce          2 (g-1)/g x operand
                    all-to-all          (g-1)/g x operand
                    collective-permute  1 x operand

Shapes in partitioned HLO are already per-device, so every number is
per-device per-step.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_WHILE_RE = re.compile(
    r"while\(.*?\),\s*condition=%([\w\.\-]+),\s*body=%([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%([\w\.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")

_SKIP_BYTES_OPS = (
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota",
    # control flow (carries are aliased in place, not traffic):
    "while", "conditional", "call",
    # dtype-legalization + layout artifacts (XLA:CPU materializes bf16
    # compute through f32 converts; TPU does not):
    "convert", "broadcast", "reshape",
    # raw un-fused elementwise (XLA:CPU leaves many elementwise ops
    # outside fusions; on TPU these fuse into neighbouring ops — counting
    # them would bill the same tensor many times over):
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "compare", "select", "exponential", "tanh", "negate", "and", "or",
    "not", "xor", "sign", "rsqrt", "sqrt", "log", "floor", "ceil", "abs",
    "power", "remainder", "clamp", "expm1", "log1p", "atan2",
)

# ops where the natural cost is the moved slice, not the full operand
# (a while-loop DUS writes one slice per trip; billing the whole ys
# buffer each iteration would overcount by the trip count)
_SLICE_OPS = ("dynamic-update-slice", "dynamic-slice", "gather", "scatter",
              "copy", "slice", "concatenate", "pad", "reduce", "transpose")

# one operand reference, optionally preceded by its inline type (newer XLA
# prints `dot(f32[128,128]{1,0} %lhs, ...)`; older prints `dot(%lhs, ...)`)
_OPERAND_TOKEN_RE = re.compile(
    r"(?:([a-z0-9]+\[[\d,]*\](?:\{[\d,]*\})?)\s+)?%([\w\.\-]+)")


def _operands(s: str, shapes: dict) -> list:
    """[(name, shape_str)] per %operand; inline type wins over the defining
    instruction's recorded result type."""
    return [(name, shp if shp else shapes.get(name, ""))
            for shp, name in _OPERAND_TOKEN_RE.findall(s)]


def _shape_bytes_all(s: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(s):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_shape(s: str):
    m = _SHAPE_RE.search(s)
    if not m:
        return None, []
    dt, dims = m.groups()
    return dt, [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    body: str          # everything right of '='

    @property
    def op(self) -> str:
        # op name appears right after the result shape(s)
        m = re.search(r"(?:\)|\]|\}) ([\w\-]+)\(", self.body)
        if m:
            return m.group(1)
        m = re.search(r"([\w\-]+)\(", self.body)
        return m.group(1) if m else ""


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict       # %name -> shape string (result type prefix)


def _split_computations(text: str):
    comps = {}
    cur = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if line.endswith("{") and ") ->" in line:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            name, body = m.groups()
            cur.instrs.append(Instr(name, body))
            # result type: text before the op call
            cur.shapes[name] = body.split(" ")[0] if body else ""
            # tuple results: capture full prefix up to the op name
            mm = re.match(r"^((?:\([^)]*\)|\S+))", body)
            if mm:
                cur.shapes[name] = mm.group(1)
    return comps


def _dot_flops(instr: Instr, shapes: dict) -> float:
    body = instr.body
    dt, result_dims = _first_shape(body)
    if dt is None:
        return 0.0
    import math
    result = math.prod(result_dims) if result_dims else 1
    # contracted size from lhs operand shape + lhs_contracting_dims
    ops = re.search(r"\bdot\(([^)]*)\)", body)
    if not ops:
        return 0.0
    opnds = _operands(ops.group(1), shapes)
    _, lhs_dims = _first_shape(opnds[0][1]) if opnds else (None, [])
    mC = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", body)
    contracted = 1
    if mC and mC.group(1) and lhs_dims:
        for d in mC.group(1).split(","):
            i = int(d)
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
    return 2.0 * result * contracted


def _instr_bytes(instr: Instr, shapes: dict, comps: dict | None = None) -> int:
    op = instr.op
    if op == "fusion" and comps is not None:
        # XLA:CPU wraps single elementwise ops in kLoop fusions
        # ("wrapped_tanh"); classify the fusion by its root op so the
        # skip/slice rules still apply.  Multi-op fusions are genuine
        # fused chains and billed operands+result (the TPU-like cost).
        m = _CALLS_RE.search(instr.body)
        callee = comps.get(m.group(1)) if m else None
        if callee is not None and callee.instrs:
            real = [i for i in callee.instrs
                    if i.op not in ("parameter", "constant")]
            root = callee.instrs[-1]
            result = _shape_bytes_all(instr.body.split(" fusion(")[0])
            if len(real) <= 1 and root.op in _SKIP_BYTES_OPS:
                return 0
            if root.op in ("bitcast", "convert", "broadcast", "reshape",
                           "transpose", "copy"):
                return result            # layout/dtype root: one write
            if root.op in _SLICE_OPS:
                ops = re.search(r"\bfusion\(([^)]*)\)", instr.body)
                sizes = [_shape_bytes_all(shp) for _, shp in
                         _operands(ops.group(1), shapes)] if ops else []
                sizes = [s for s in sizes if s > 0]
                small = min(sizes) if sizes else result
                return 2 * min(small, result)
        op = "fusion"
    if op in _SKIP_BYTES_OPS or not op:
        return 0
    result = _shape_bytes_all(instr.body.split(f" {op}(")[0])
    if op == "dynamic-update-slice":
        # write slice + read slice: operand 1 is the update
        ops = re.search(r"dynamic-update-slice\(([^)]*)\)", instr.body)
        if ops:
            parts = _operands(ops.group(1), shapes)
            if len(parts) >= 2 and parts[1][1]:
                return 2 * _shape_bytes_all(parts[1][1])
        return 0
    if op in ("dynamic-slice", "gather", "slice"):
        return 2 * result          # read slice + write result
    if op in ("copy", "transpose", "reduce", "pad", "concatenate"):
        return 2 * result
    if op == "scatter":
        ops = re.search(r"scatter\(([^)]*)\)", instr.body)
        if ops:
            parts = _operands(ops.group(1), shapes)
            if parts and parts[-1][1]:
                return 2 * _shape_bytes_all(parts[-1][1])
        return 2 * result
    total = result
    ops = re.search(rf"\b{re.escape(op)}\(([^)]*)\)", instr.body)
    if ops:
        for _, shp in _operands(ops.group(1), shapes):
            total += _shape_bytes_all(shp)
    return total


def _group_size(body: str) -> int:
    m = _GROUPS_RE.search(body)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(body)
    if m:
        return len(m.group(1).split(","))
    return 1


def _collective_link_bytes(instr: Instr) -> tuple:
    """(op, link_bytes) or (None, 0)."""
    body = instr.body
    for op in _COLL_OPS:
        if re.search(rf"\b{op}(-start)?\(", body):
            is_start = f"{op}-start(" in body
            prefix = body.split(f" {op}", 1)[0]
            sizes = [_shape_bytes_all(s) for s in
                     re.findall(r"\w+\[[\d,]*\]", prefix)]
            sizes = [s for s in sizes if s > 0]
            if not sizes:
                return None, 0.0
            nbytes = sizes[-1] if (is_start and len(sizes) > 1) else sum(sizes)
            g = _group_size(body)
            f = (g - 1) / g if g > 1 else 0.0
            if op == "all-reduce":
                return op, 2 * f * nbytes
            if op == "collective-permute":
                return op, float(nbytes)
            if op == "reduce-scatter":
                return op, float((g - 1) * nbytes)
            return op, f * nbytes
    return None, 0.0


@dataclasses.dataclass
class HloCost:
    flops: float
    bytes: float
    link_bytes: float
    coll_bytes: dict
    coll_count: dict
    while_trips: list      # (body_name, trip) for inspection

    def summary(self) -> dict:
        return {"flops": self.flops, "bytes": self.bytes,
                "link_bytes": self.link_bytes,
                "raw_bytes": dict(self.coll_bytes),
                "counts": dict(self.coll_count)}


def _trip_count(cond: Computation) -> int:
    best = 1
    for ins in cond.instrs:
        for m in _CONST_S32_RE.finditer(ins.body):
            best = max(best, int(m.group(1)))
    return best


def analyze_hlo(text: str) -> HloCost:
    comps = _split_computations(text)
    fused = set()
    for c in comps.values():
        for ins in c.instrs:
            if " fusion(" in ins.body or "to_apply=" in ins.body:
                for m in _CALLS_RE.finditer(ins.body):
                    fused.add(m.group(1))

    memo = {}
    trips_seen = []

    def cost_of(name: str, in_fusion: bool):
        key = (name, in_fusion)
        if key in memo:
            return memo[key]
        c = comps.get(name)
        if c is None:
            return (0.0, 0.0, 0.0, {}, {})
        flops = byts = link = 0.0
        cb: dict = defaultdict(float)
        cc: dict = defaultdict(float)
        for ins in c.instrs:
            flops += _dot_flops(ins, c.shapes)
            if not in_fusion:
                byts += _instr_bytes(ins, c.shapes, comps)
                op, lb = _collective_link_bytes(ins)
                if op:
                    link += lb
                    cb[op] += lb
                    cc[op] += 1
            # recurse: fusions (flops only), whiles, conditionals, calls
            mw = _WHILE_RE.search(ins.body)
            if mw:
                cond_name, body_name = mw.groups()
                trip = _trip_count(comps[cond_name]) if cond_name in comps \
                    else 1
                trips_seen.append((body_name, trip))
                bf, bb, bl, bcb, bcc = cost_of(body_name, in_fusion)
                flops += trip * bf
                byts += trip * bb
                link += trip * bl
                for k, v in bcb.items():
                    cb[k] += trip * v
                for k, v in bcc.items():
                    cc[k] += trip * v
                continue
            mb = _BRANCHES_RE.search(ins.body)
            if mb:
                # conditional: worst-case branch
                best = (0.0, 0.0, 0.0, {}, {})
                for br in mb.group(1).split(","):
                    br = br.strip().lstrip("%")
                    cand = cost_of(br, in_fusion)
                    if cand[0] + cand[2] > best[0] + best[2]:
                        best = cand
                flops += best[0]
                byts += best[1]
                link += best[2]
                continue
            for m in _CALLS_RE.finditer(ins.body):
                callee = m.group(1)
                # fusion/to_apply subcomputations: dots only
                cf, _, _, _, _ = cost_of(callee, True)
                flops += cf
        out = (flops, byts, link, dict(cb), dict(cc))
        memo[key] = out
        return out

    entry = None
    for raw in text.splitlines():
        if raw.strip().startswith("ENTRY"):
            m = _COMP_HDR_RE.match(raw.strip())
            if m:
                entry = m.group(1)
                break
    if entry is None:
        entry = list(comps)[-1]
    flops, byts, link, cb, cc = cost_of(entry, False)
    return HloCost(flops=flops, bytes=byts, link_bytes=link, coll_bytes=cb,
                   coll_count=cc, while_trips=trips_seen)


# Backwards-compatible wrapper used by earlier callers/tests
@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: dict
    per_op_count: dict
    link_bytes: float
    by_line: list

    def summary(self) -> dict:
        return {"link_bytes": self.link_bytes,
                "counts": dict(self.per_op_count),
                "raw_bytes": dict(self.per_op_bytes)}


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Trip-count-aware collective stats (see analyze_hlo)."""
    cost = analyze_hlo(hlo_text)
    return CollectiveStats(per_op_bytes=cost.coll_bytes,
                           per_op_count=cost.coll_count,
                           link_bytes=cost.link_bytes, by_line=[])
