import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""FlyWire connectome simulation dry-run on the production mesh — the
paper's own workload mapped onto 256/512 TPU cores.

    PYTHONPATH=src python -m repro.launch.flywire_dryrun \
        [--cores 256|512] [--scale bench|full] [--scheme event|bitmap]

Pipeline: synthetic FlyWire graph -> greedy SAR capacity partitioning ->
pad to the mesh core count -> SNN-dCSR -> lower + compile the shard_map
event-driven simulation step (scan over one delay window) on a flat
device mesh.  Records the same memory/cost/collective analysis as the LM
dry-run (JSON to experiments/dryrun/).
"""

import argparse        # noqa: E402
import functools       # noqa: E402
import json            # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np     # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402
from jax.experimental.shard_map import shard_map            # noqa: E402

from repro.configs.flywire import CONFIG, SMOKE             # noqa: E402
from repro.core import (CoreBudget, caps_from_budget,       # noqa: E402
                        greedy_partition, synthetic_flywire_cached)
from repro.core.dcsr import build_dcsr                      # noqa: E402
from repro.core.distributed import AXIS, DistConfig         # noqa: E402
from repro.core.exchange import (DistArrays, Topology,      # noqa: E402
                                 get_scheme)
from repro.core.partition import pad_to_uniform             # noqa: E402
from repro.core.step import SimCarry, scan_steps            # noqa: E402
from repro.launch.hlo import analyze_hlo                    # noqa: E402
from repro.launch.mesh import make_flat_mesh                # noqa: E402


def abstract_dist_arrays(d, n_glob):
    """ShapeDtypeStruct stand-ins for DistArrays (no host materialization
    of the regrouped event-scheme structures needed to lower)."""
    Pn, U, S = d.n_parts, d.part_size, d.s_max
    f32 = jnp.float32
    i32 = jnp.int32
    sd = jax.ShapeDtypeStruct
    return DistArrays(
        syn_src=sd((Pn, S), i32), syn_tgt=sd((Pn, S), i32),
        syn_w=sd((Pn, S), f32),
        out_indptr=sd((Pn, n_glob + 1), i32),
        out_tgt=sd((Pn, S), i32), out_w=sd((Pn, S), f32),
        pad_mask=sd((Pn, U), jnp.bool_),
        src_gfo=sd((Pn, U), i32),
    )


def abstract_stimulus(sim, Pn, U):
    """The legacy masked sugar+background stimulus with abstract [P, U]
    mask leaves (same pytree the concrete shard_stimulus path produces)."""
    from repro.exp.stimulus import legacy_stimulus
    stim = legacy_stimulus(sim, Pn * U, masked=True).to_masked(Pn * U)
    sd = jax.ShapeDtypeStruct
    return jax.tree.map(lambda _: sd((Pn, U), jnp.bool_), stim)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cores", type=int, default=256)
    ap.add_argument("--scale", choices=["bench", "full"], default="full")
    ap.add_argument("--scheme", choices=["event", "bitmap"], default="event")
    ap.add_argument("--steps", type=int, default=18,
                    help="steps per lowered scan (one 1.8ms delay window)")
    ap.add_argument("--capacity", type=int, default=256,
                    help="event capacity K per core per step (provisioned "
                         "activity — the Loihi 'cost ~ spikes' lever)")
    ap.add_argument("--budget", type=int, default=65536,
                    help="synapse delivery budget per core per step")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    fw = CONFIG if args.scale == "full" else SMOKE
    n, syn = ((fw.n_neurons, fw.target_synapses) if args.scale == "full"
              else (20_000, 600_000))
    t0 = time.time()
    c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn)
    p = greedy_partition(c, caps_from_budget(CoreBudget.tpu_vmem(), "sar"),
                         scheme="sar")
    p = pad_to_uniform(p, args.cores, c.n)
    d = build_dcsr(c, p, quantize_bits=9)
    print(f"[flywire-dryrun] graph {c.n}n/{c.nnz}syn -> {d.n_parts} cores, "
          f"U={d.part_size}, S_max={d.s_max} "
          f"(prep {time.time()-t0:.0f}s)")

    mesh = make_flat_mesh(args.cores)
    from repro.core.capacity import CapacityConfig
    cfg = DistConfig(sim=fw.sim, scheme=args.scheme,
                     capacity=CapacityConfig(spike_capacity=args.capacity,
                                             syn_budget=args.budget))
    Pn, U = d.n_parts, d.part_size
    arrs = abstract_dist_arrays(d, Pn * U)
    stim = abstract_stimulus(fw.sim, Pn, U)
    from repro.core.neuron import LIFState
    from repro.exp.probes import NO_PROBES
    sd = jax.ShapeDtypeStruct
    keys_aval = jax.eval_shape(
        lambda: jax.random.split(jax.random.PRNGKey(0), Pn))
    scheme = get_scheme(args.scheme)
    carry = SimCarry(
        lif=LIFState(v=sd((Pn, U), jnp.int32), g=sd((Pn, U), jnp.int32),
                     refrac=sd((Pn, U), jnp.int32)),
        ring=sd((Pn, fw.sim.params.delay_steps, U), jnp.bool_),
        ptr=sd((Pn,), jnp.int32),
        key=keys_aval,
        counts=sd((Pn, U), jnp.int32),
        dropped=sd((Pn,), jnp.int32),
        # state structure must match the stimulus (Compose.step zips them)
        stim=stim.init_state(U),
        stats=scheme.init_stats(),
    )
    topo = Topology(Pn, U, axis=AXIS)

    def run_window(carry_in, arr, st):
        carry_in = jax.tree.map(lambda x: x[0], carry_in)
        arr = jax.tree.map(lambda x: x[0], arr)
        st = jax.tree.map(lambda x: x[0], st)
        cc, _ = scan_steps(scheme, arr, carry_in, st, cfg.sim, cfg.capacity,
                           topo, NO_PROBES, args.steps,
                           pad_mask=arr.pad_mask)
        return jax.tree.map(lambda x: x[None], cc)

    spec_c = jax.tree.map(lambda _: P("cores"), carry)
    spec_a = jax.tree.map(lambda _: P("cores"), arrs)
    spec_s = jax.tree.map(lambda _: P("cores"), stim)
    fn = shard_map(run_window, mesh=mesh, in_specs=(spec_c, spec_a, spec_s),
                   out_specs=spec_c, check_rep=False)
    sh_c = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_c)
    sh_a = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_a)
    sh_s = jax.tree.map(lambda s: NamedSharding(mesh, s), spec_s)

    t1 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=(sh_c, sh_a, sh_s),
                          donate_argnums=0).lower(carry, arrs, stim)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text())
    peak = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes)
    rec = {
        "arch": "flywire-snn", "cell": f"{args.scale}_{args.scheme}",
        "mesh": f"cores{args.cores}", "n_devices": args.cores,
        "kind": "simulate", "steps_per_window": args.steps,
        "compile_s": round(time.time() - t1, 1),
        "memory": {"argument_bytes": mem.argument_size_in_bytes,
                   "temp_bytes": mem.temp_size_in_bytes,
                   "peak_device_bytes": peak},
        "cost": {"flops_per_device": hlo.flops,
                 "bytes_per_device": hlo.bytes},
        "collectives": hlo.summary(),
    }
    os.makedirs(args.out, exist_ok=True)
    with open(os.path.join(
            args.out, f"flywire_{args.scale}_{args.scheme}_"
            f"c{args.cores}_k{args.capacity}.json"), "w") as f:
        json.dump(rec, f, indent=1)
    # roofline terms for one delay window (18 steps of 0.1 ms)
    comp = hlo.flops / 197e12
    memt = hlo.bytes / 819e9
    coll = hlo.link_bytes / 50e9
    print(f"[flywire-dryrun] compile {rec['compile_s']}s  "
          f"peak/core {peak/2**20:.1f} MiB  "
          f"window terms: compute {comp*1e6:.1f}us  "
          f"memory {memt*1e6:.1f}us  collective {coll*1e6:.1f}us  "
          f"counts {hlo.coll_count}")
    print("  memory_analysis:", mem)
    sim_window_ms = args.steps * fw.sim.params.dt
    bound = max(comp, memt, coll)
    print(f"[flywire-dryrun] modelled wall/window {bound*1e3:.3f} ms vs "
          f"simulated {sim_window_ms:.1f} ms -> "
          f"{sim_window_ms/1e3/bound:.0f}x faster than realtime (model)")


if __name__ == "__main__":
    main()
