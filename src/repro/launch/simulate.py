"""FlyWire simulation driver (the paper's workload as a CLI).

    PYTHONPATH=src python -m repro.launch.simulate --scale smoke \
        --scenario sugar_feeding --engine event --trials 3
    PYTHONPATH=src python -m repro.launch.simulate --scale full --parity
    PYTHONPATH=src python -m repro.launch.simulate --distributed --cores 4

--scenario selects a registered stimulus scenario (repro.exp.scenarios);
--trials > 1 runs a vmapped seed batch — one compiled call — and reports
trial-averaged rates (on the distributed path too: the unified step core
batches the partitioned scan the same way).  --distributed partitions
with the paper's greedy capacity scheme and runs the shard_map simulator
with the same stimulus pytree (one partition per host device; set
XLA_FLAGS=--xla_force_host_platform_device_count=N first, or use
--emulate); --dist-scheme selects the registered exchange scheme
(bitmap | event | blocked).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.configs.flywire import CONFIG, CONFIG_1MS, SMOKE
from repro.core import (CoreBudget, SimConfig, caps_from_budget,
                        greedy_partition, parity, spike_rates_hz,
                        synthetic_flywire_cached)
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.exp import (available_scenarios, build_scenario, get_scenario,
                       run_dist_trials, run_trials)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["smoke", "bench", "full"],
                    default="bench")
    from repro.core import available_engines
    ap.add_argument("--engine", default="event",
                    choices=available_engines())
    ap.add_argument("--scenario", default="sugar_feeding",
                    choices=available_scenarios())
    ap.add_argument("--dt", type=float, default=0.1, choices=[0.1, 1.0])
    ap.add_argument("--fixed-point", action="store_true",
                    help="run the int32 Q19.12 integration path (the "
                         "Loihi-faithful arithmetic; CI smokes it on "
                         "every push)")
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--t-ms", type=float, default=0.0)
    ap.add_argument("--background-hz", type=float, default=None,
                    help="override the scenario's background_hz param "
                         "(0 turns an always-on background off)")
    ap.add_argument("--parity", action="store_true",
                    help="compare against the float csr reference")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--emulate", action="store_true")
    ap.add_argument("--cores", type=int, default=4)
    from repro.core import available_schemes
    ap.add_argument("--dist-scheme", default="event",
                    choices=sorted(set(available_schemes()) - {"local"}))
    args = ap.parse_args()

    fw = {"smoke": SMOKE, "bench": dataclasses.replace(
        SMOKE, n_neurons=20_000, target_synapses=600_000, t_sim_ms=100.0),
        "full": (CONFIG if args.dt == 0.1 else CONFIG_1MS)}[args.scale]
    c = synthetic_flywire_cached(n=fw.n_neurons, seed=0,
                                 target_synapses=fw.target_synapses)
    print(f"[simulate] connectome: {c.stats()}")
    t_ms = args.t_ms or fw.t_sim_ms
    cfg = dataclasses.replace(fw.sim, engine=args.engine,
                              fixed_point=fw.sim.fixed_point
                              or args.fixed_point)
    t_steps = int(round(t_ms / cfg.params.dt))
    dt_ms = cfg.params.dt

    scen = get_scenario(args.scenario)
    # FlyWireConfig stays the source of truth for the sugar population
    # wherever the scenario exposes the matching params
    overrides = {}
    if "n_sugar" in scen.defaults:
        overrides["n_sugar"] = fw.n_sugar
    if "rate_hz" in scen.defaults:
        overrides["rate_hz"] = fw.sugar_rate_hz
    if args.background_hz is not None:
        if "background_hz" in scen.defaults:
            overrides["background_hz"] = args.background_hz
        else:
            print(f"[simulate] note: scenario {scen.name!r} takes no "
                  f"background_hz; --background-hz ignored")
    stim = build_scenario(args.scenario, c, cfg, **overrides)
    print(f"[simulate] scenario {scen.name!r}: {scen.description}")

    if args.distributed:
        caps = caps_from_budget(CoreBudget.tpu_vmem(), "sar")
        p = greedy_partition(c, caps, scheme="sar")
        from repro.core.partition import pad_to_uniform
        p = pad_to_uniform(p, args.cores, c.n)
        d = build_dcsr(c, p, quantize_bits=cfg.quantize_bits)
        print(f"[simulate] distributed over {d.n_parts} partitions "
              f"(U={d.part_size}, S_max={d.s_max}, "
              f"scheme={args.dist_scheme})")
        dcfg = DistConfig(sim=cfg, scheme=args.dist_scheme)
        t0 = time.time()
        if args.trials > 1:
            res = run_dist_trials(d, dcfg, t_steps, seeds=args.trials,
                                  emulate=args.emulate, stimulus=stim)
            mean_counts = np.asarray(res.counts, np.float64).mean(axis=0)
            dropped = int(np.asarray(res.dropped).sum())
        else:
            res = simulate_distributed(d, dcfg, t_steps, seed=0,
                                       emulate=args.emulate, stimulus=stim)
            mean_counts = res.counts.astype(np.float64)
            dropped = res.dropped
        stats = "".join(f" {k}={int(np.asarray(v).sum())}"
                        for k, v in res.stats.items())
        print(f"[simulate] {max(args.trials, 1)} trial(s) x {t_steps} steps "
              f"in {time.time()-t0:.2f}s (dropped={dropped}{stats})")
    else:
        t0 = time.time()
        res = run_trials(c, cfg, t_steps, stimulus=stim, seeds=args.trials)
        mean_counts = np.asarray(res.counts, np.float64).mean(axis=0)
        dropped = int(np.asarray(res.dropped).sum())
        print(f"[simulate] {args.trials} trial(s) x {t_steps} steps in "
              f"{time.time()-t0:.2f}s (dropped={dropped})")

    rates = np.asarray(spike_rates_hz(mean_counts, t_steps, dt_ms))
    active = (rates > 0.5).sum()
    print(f"[simulate] mean total spikes {mean_counts.sum():.1f}, "
          f"active neurons {active} ({active/c.n:.2%}), "
          f"mean active rate {rates[rates>0.5].mean() if active else 0:.1f} Hz")

    if args.parity:
        ref_cfg = SimConfig(engine="csr", params=cfg.params,
                            poisson_to_v=True)
        ref_stim = build_scenario(args.scenario, c, ref_cfg, **overrides)
        ra = run_trials(c, ref_cfg, t_steps, stimulus=ref_stim,
                        seeds=[10 + i for i in range(args.trials)]
                        ).mean_rates_hz(t_steps, dt_ms)
        rb = run_trials(c, cfg, t_steps, stimulus=stim,
                        seeds=[20 + i for i in range(args.trials)]
                        ).mean_rates_hz(t_steps, dt_ms)
        print("[simulate] parity vs float reference:",
              parity(ra, rb).summary())


if __name__ == "__main__":
    main()
