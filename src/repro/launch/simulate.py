"""FlyWire simulation driver (the paper's workload as a CLI).

    PYTHONPATH=src python -m repro.launch.simulate --scale smoke \
        --scenario sugar_feeding --engine event --trials 3
    PYTHONPATH=src python -m repro.launch.simulate --scale full --parity
    PYTHONPATH=src python -m repro.launch.simulate --distributed --cores 4

--scenario selects a registered stimulus scenario (repro.exp.scenarios);
--trials > 1 runs a vmapped seed batch — one compiled call — and reports
trial-averaged rates (on the distributed path too: the unified step core
batches the partitioned scan the same way).  --distributed partitions
with the paper's greedy capacity scheme and runs the shard_map simulator
with the same stimulus pytree (one partition per host device; set
XLA_FLAGS=--xla_force_host_platform_device_count=N first, or use
--emulate); --dist-scheme selects the registered exchange scheme
(bitmap | event | blocked).
"""

from __future__ import annotations

import argparse
import contextlib
import dataclasses
import time

import numpy as np

from repro import obs
from repro.configs.flywire import CONFIG, CONFIG_1MS, SMOKE
from repro.core import (CoreBudget, SimConfig, caps_from_budget,
                        greedy_partition, parity, spike_rates_hz,
                        synthetic_flywire_cached)
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.exp import (available_scenarios, build_scenario, get_scenario,
                       run_dist_trials, run_trials)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["smoke", "bench", "full"],
                    default="bench")
    from repro.core import available_engines
    ap.add_argument("--engine", default="event",
                    choices=available_engines())
    ap.add_argument("--scenario", default="sugar_feeding",
                    choices=available_scenarios())
    ap.add_argument("--dt", type=float, default=0.1, choices=[0.1, 1.0])
    ap.add_argument("--fixed-point", action="store_true",
                    help="run the int32 Q19.12 integration path (the "
                         "Loihi-faithful arithmetic; CI smokes it on "
                         "every push)")
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--t-ms", type=float, default=0.0)
    ap.add_argument("--background-hz", type=float, default=None,
                    help="override the scenario's background_hz param "
                         "(0 turns an always-on background off)")
    ap.add_argument("--parity", action="store_true",
                    help="compare against the float csr reference")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--emulate", action="store_true")
    ap.add_argument("--cores", type=int, default=4)
    from repro.core import available_schemes
    ap.add_argument("--dist-scheme", default="event",
                    choices=sorted(set(available_schemes()) - {"local"}))
    # Chunked supervision / checkpoint-resume (docs/resilience.md): the
    # CI kill-and-resume smoke drives these end to end.
    ap.add_argument("--chunk-steps", type=int, default=0,
                    help="supervise the run in K-step chunks "
                         "(bit-identical to the monolithic scan; 0 = off)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="checkpoint the carry at chunk boundaries")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--checkpoint-dir")
    ap.add_argument("--health", action="store_true",
                    help="enable in-scan health sentinels + chunk-boundary "
                         "threshold checks")
    ap.add_argument("--max-drop-rate", type=float, default=None,
                    help="health threshold: dropped synapse events per "
                         "step (implies --health)")
    ap.add_argument("--inject-fail-at-chunk", type=int, default=0,
                    help="deterministic mid-run kill: run only N chunks "
                         "then exit (requires --chunk-steps and "
                         "--checkpoint-dir; resume with --resume)")
    ap.add_argument("--digest", action="store_true",
                    help="print a sha256 over raster+counts (enables the "
                         "raster probe; the kill-and-resume smoke's "
                         "bit-identity check)")
    # Telemetry + profiling (docs/observability.md): the CI telemetry
    # smoke drives --telemetry end to end (emit -> schema check -> report).
    ap.add_argument("--telemetry", default=None, metavar="PATH",
                    help="stream JSONL telemetry events to PATH "
                         "(chunk/compile/span/health records; inspect with "
                         "python -m repro.obs.report PATH)")
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="wrap the run in jax.profiler.trace(DIR) "
                         "(TensorBoard-loadable XLA trace)")
    args = ap.parse_args()

    supervised = bool(args.chunk_steps or args.checkpoint_dir or args.resume
                      or args.health or args.max_drop_rate is not None)
    if supervised and args.trials > 1:
        ap.error("chunked supervision flags require --trials 1")
    if args.inject_fail_at_chunk and not (args.chunk_steps
                                          and args.checkpoint_dir):
        ap.error("--inject-fail-at-chunk requires --chunk-steps and "
                 "--checkpoint-dir")

    with contextlib.ExitStack() as stack:
        if args.telemetry:
            stack.enter_context(obs.telemetry(args.telemetry))
        stack.enter_context(obs.profile_trace(args.profile))
        _run(args, supervised)
    if args.telemetry:
        print(f"[simulate] telemetry stream: {args.telemetry} "
              f"(python -m repro.obs.report {args.telemetry})")


def _fmt_stats(stats: dict) -> str:
    """Render result stats for the run line; nested dicts (the telemetry
    compile-cache snapshot) get a compact hit/miss summary."""
    out = []
    for k, v in stats.items():
        if isinstance(v, dict):
            if k == "compile_cache":
                out.append(f" cache_hits={v['hits']}"
                           f"/{v['hits'] + v['misses']}")
            continue
        out.append(f" {k}={int(np.asarray(v).sum())}")
    return "".join(out)


def _run(args, supervised: bool):
    fw = {"smoke": SMOKE, "bench": dataclasses.replace(
        SMOKE, n_neurons=20_000, target_synapses=600_000, t_sim_ms=100.0),
        "full": (CONFIG if args.dt == 0.1 else CONFIG_1MS)}[args.scale]
    c = synthetic_flywire_cached(n=fw.n_neurons, seed=0,
                                 target_synapses=fw.target_synapses)
    print(f"[simulate] connectome: {c.stats()}")
    t_ms = args.t_ms or fw.t_sim_ms
    cfg = dataclasses.replace(fw.sim, engine=args.engine,
                              fixed_point=fw.sim.fixed_point
                              or args.fixed_point)
    if args.health or args.max_drop_rate is not None:
        from repro.core import HealthConfig
        cfg = dataclasses.replace(
            cfg, health=HealthConfig(max_drop_rate=args.max_drop_rate))
    t_steps = int(round(t_ms / cfg.params.dt))
    dt_ms = cfg.params.dt
    if args.inject_fail_at_chunk:
        # deterministic "kill": stop after N supervised chunks; the
        # checkpoints on disk are exactly what a SIGKILL would leave
        t_steps = min(t_steps, args.inject_fail_at_chunk * args.chunk_steps)
    probes = None
    if args.digest:
        from repro.exp.probes import ProbeSpec
        probes = ProbeSpec(raster=True)
    chunk_kw = dict(chunk_steps=args.chunk_steps or None,
                    checkpoint_dir=args.checkpoint_dir, resume=args.resume)

    scen = get_scenario(args.scenario)
    # FlyWireConfig stays the source of truth for the sugar population
    # wherever the scenario exposes the matching params
    overrides = {}
    if "n_sugar" in scen.defaults:
        overrides["n_sugar"] = fw.n_sugar
    if "rate_hz" in scen.defaults:
        overrides["rate_hz"] = fw.sugar_rate_hz
    if args.background_hz is not None:
        if "background_hz" in scen.defaults:
            overrides["background_hz"] = args.background_hz
        else:
            print(f"[simulate] note: scenario {scen.name!r} takes no "
                  f"background_hz; --background-hz ignored")
    stim = build_scenario(args.scenario, c, cfg, **overrides)
    print(f"[simulate] scenario {scen.name!r}: {scen.description}")

    if args.distributed:
        caps = caps_from_budget(CoreBudget.tpu_vmem(), "sar")
        p = greedy_partition(c, caps, scheme="sar")
        from repro.core.partition import pad_to_uniform
        p = pad_to_uniform(p, args.cores, c.n)
        d = build_dcsr(c, p, quantize_bits=cfg.quantize_bits)
        print(f"[simulate] distributed over {d.n_parts} partitions "
              f"(U={d.part_size}, S_max={d.s_max}, "
              f"scheme={args.dist_scheme})")
        dcfg = DistConfig(sim=cfg, scheme=args.dist_scheme)
        t0 = time.time()
        raster = None
        if args.trials > 1:
            res = run_dist_trials(d, dcfg, t_steps, seeds=args.trials,
                                  emulate=args.emulate, stimulus=stim)
            mean_counts = np.asarray(res.counts, np.float64).mean(axis=0)
            dropped = int(np.asarray(res.dropped).sum())
        else:
            res = simulate_distributed(d, dcfg, t_steps, seed=0,
                                       emulate=args.emulate, stimulus=stim,
                                       probes=probes, **chunk_kw)
            mean_counts = res.counts.astype(np.float64)
            dropped = res.dropped
            raster = res.raster
        stats = _fmt_stats(res.stats)
        print(f"[simulate] {max(args.trials, 1)} trial(s) x {t_steps} steps "
              f"in {time.time()-t0:.2f}s (dropped={dropped}{stats})")
    elif supervised or (args.telemetry and args.trials == 1):
        # a single-trial telemetry run goes through simulate() so the
        # full run_start/chunk/run_end event stream exists
        from repro.core import simulate
        t0 = time.time()
        res = simulate(c, cfg, t_steps, stimulus=stim, probes=probes,
                       seed=0, **chunk_kw)
        mean_counts = np.asarray(res.counts, np.float64)
        dropped = int(np.asarray(res.dropped))
        raster = res.raster
        stats = _fmt_stats(res.stats)
        print(f"[simulate] 1 trial x {t_steps} supervised steps "
              f"(K={args.chunk_steps or t_steps}) in {time.time()-t0:.2f}s "
              f"(dropped={dropped}{stats})")
    else:
        t0 = time.time()
        raster = None
        res = run_trials(c, cfg, t_steps, stimulus=stim, seeds=args.trials,
                         probes=probes)
        mean_counts = np.asarray(res.counts, np.float64).mean(axis=0)
        dropped = int(np.asarray(res.dropped).sum())
        print(f"[simulate] {args.trials} trial(s) x {t_steps} steps in "
              f"{time.time()-t0:.2f}s (dropped={dropped})")
    if args.inject_fail_at_chunk:
        print(f"[simulate] injected kill after chunk "
              f"{args.inject_fail_at_chunk} — checkpoints in "
              f"{args.checkpoint_dir}; rerun with --resume to continue")
        return
    if args.digest:
        import hashlib
        h = hashlib.sha256()
        if raster is not None:
            h.update(np.ascontiguousarray(np.asarray(raster)).tobytes())
        h.update(np.ascontiguousarray(
            mean_counts.astype(np.int64)).tobytes())
        print(f"[simulate] digest {h.hexdigest()}")

    rates = np.asarray(spike_rates_hz(mean_counts, t_steps, dt_ms))
    active = (rates > 0.5).sum()
    print(f"[simulate] mean total spikes {mean_counts.sum():.1f}, "
          f"active neurons {active} ({active/c.n:.2%}), "
          f"mean active rate {rates[rates>0.5].mean() if active else 0:.1f} Hz")

    if args.parity:
        ref_cfg = SimConfig(engine="csr", params=cfg.params,
                            poisson_to_v=True)
        ref_stim = build_scenario(args.scenario, c, ref_cfg, **overrides)
        ra = run_trials(c, ref_cfg, t_steps, stimulus=ref_stim,
                        seeds=[10 + i for i in range(args.trials)]
                        ).mean_rates_hz(t_steps, dt_ms)
        rb = run_trials(c, cfg, t_steps, stimulus=stim,
                        seeds=[20 + i for i in range(args.trials)]
                        ).mean_rates_hz(t_steps, dt_ms)
        print("[simulate] parity vs float reference:",
              parity(ra, rb).summary())


if __name__ == "__main__":
    main()
