"""FlyWire simulation driver (the paper's workload as a CLI).

    PYTHONPATH=src python -m repro.launch.simulate --scale smoke \
        --engine event --trials 3
    PYTHONPATH=src python -m repro.launch.simulate --scale full --parity
    PYTHONPATH=src python -m repro.launch.simulate --distributed --cores 4

--distributed partitions with the paper's greedy capacity scheme and runs
the shard_map simulator (one partition per host device; set
XLA_FLAGS=--xla_force_host_platform_device_count=N first, or use
--emulate).
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

from repro.configs.flywire import CONFIG, CONFIG_1MS, SMOKE
from repro.core import (CoreBudget, SimConfig, caps_from_budget,
                        greedy_partition, parity, simulate,
                        synthetic_flywire_cached)
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.engine import spike_rates_hz


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", choices=["smoke", "bench", "full"],
                    default="bench")
    from repro.core import available_engines
    ap.add_argument("--engine", default="event",
                    choices=available_engines())
    ap.add_argument("--dt", type=float, default=0.1, choices=[0.1, 1.0])
    ap.add_argument("--trials", type=int, default=1)
    ap.add_argument("--t-ms", type=float, default=0.0)
    ap.add_argument("--background-hz", type=float, default=0.0)
    ap.add_argument("--parity", action="store_true",
                    help="compare against the float csr reference")
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--emulate", action="store_true")
    ap.add_argument("--cores", type=int, default=4)
    args = ap.parse_args()

    fw = {"smoke": SMOKE, "bench": dataclasses.replace(
        SMOKE, n_neurons=20_000, target_synapses=600_000, t_sim_ms=100.0),
        "full": (CONFIG if args.dt == 0.1 else CONFIG_1MS)}[args.scale]
    c = synthetic_flywire_cached(n=fw.n_neurons, seed=0,
                                 target_synapses=fw.target_synapses)
    print(f"[simulate] connectome: {c.stats()}")
    sugar = fw.sugar_neurons()
    t_ms = args.t_ms or fw.t_sim_ms
    cfg = dataclasses.replace(fw.sim, engine=args.engine,
                              background_rate_hz=args.background_hz)
    t_steps = int(round(t_ms / cfg.params.dt))

    if args.distributed:
        caps = caps_from_budget(CoreBudget.tpu_vmem(), "sar")
        p = greedy_partition(c, caps, scheme="sar")
        from repro.core.partition import pad_to_uniform
        p = pad_to_uniform(p, args.cores, c.n)
        d = build_dcsr(c, p, quantize_bits=cfg.quantize_bits)
        print(f"[simulate] distributed over {d.n_parts} partitions "
              f"(U={d.part_size}, S_max={d.s_max})")
        dcfg = DistConfig(sim=cfg, scheme="event")
        t0 = time.time()
        res = simulate_distributed(d, dcfg, t_steps, sugar, seed=0,
                                   emulate=args.emulate)
        counts = res.counts
        print(f"[simulate] {t_steps} steps in {time.time()-t0:.2f}s "
              f"(dropped={res.dropped})")
    else:
        t0 = time.time()
        res = simulate(c, cfg, t_steps, sugar, seed=0)
        counts = np.asarray(res.counts)
        print(f"[simulate] {t_steps} steps in {time.time()-t0:.2f}s "
              f"(dropped={int(res.dropped)})")

    rates = counts / (t_ms * 1e-3)
    active = (rates > 0.5).sum()
    print(f"[simulate] total spikes {int(counts.sum())}, "
          f"active neurons {active} ({active/c.n:.2%}), "
          f"mean active rate {rates[rates>0.5].mean() if active else 0:.1f} Hz")

    if args.parity:
        ref_cfg = SimConfig(engine="csr", params=cfg.params,
                            poisson_to_v=True)
        trials_a = [np.asarray(simulate(c, ref_cfg, t_steps, sugar,
                                        seed=10 + i).counts)
                    for i in range(args.trials)]
        trials_b = [np.asarray(simulate(c, cfg, t_steps, sugar,
                                        seed=20 + i).counts)
                    for i in range(args.trials)]
        ra = np.stack(trials_a).mean(0) / (t_ms * 1e-3)
        rb = np.stack(trials_b).mean(0) / (t_ms * 1e-3)
        print("[simulate] parity vs float reference:",
              parity(ra, rb).summary())


if __name__ == "__main__":
    main()
