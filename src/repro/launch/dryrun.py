import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and record memory/cost/collective analysis.

MUST be run as its own process (the XLA_FLAGS line above executes before
any jax import, including the ones below):

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
        --cell train_4k [--multi-pod] [--out experiments/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Outputs one JSON per cell: per-device argument/temp bytes (proves fit),
per-device HLO FLOPs + bytes accessed, collective link-bytes breakdown —
the §Roofline inputs.
"""

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402

from repro.configs import all_arch_names, get_config           # noqa: E402
from repro.configs.shapes import SHAPES, cell_supported        # noqa: E402
from repro.launch.build import (analytic_bytes, build_step,    # noqa: E402
                                lower_and_compile)             # noqa: E402
from repro.launch.hlo import analyze_hlo                       # noqa: E402
from repro.launch.mesh import make_production_mesh             # noqa: E402


def run_cell(arch: str, cell: str, multi_pod: bool, out_dir: str,
             microbatches: int = 0, overrides: dict | None = None,
             tag: str = "", mesh_shape: tuple | None = None) -> dict:
    if mesh_shape is not None:
        import jax as _jax
        from jax.sharding import AxisType
        mesh = _jax.make_mesh(mesh_shape, ("data", "model"),
                              axis_types=(AxisType.Auto,) * 2)
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    built = build_step(arch, cell, mesh, microbatches=microbatches,
                       overrides=overrides)
    lowered, compiled = lower_and_compile(built, mesh)
    t1 = time.time()

    mem = compiled.memory_analysis()
    xla_cost = compiled.cost_analysis()
    xla_cost = xla_cost[0] if isinstance(xla_cost, (list, tuple)) else xla_cost
    hlo = analyze_hlo(compiled.as_text())

    mesh_name = ("x".join(str(x) for x in mesh_shape) if mesh_shape
                 else ("pod2x16x16" if multi_pod else "16x16"))
    rec = {
        "arch": arch, "cell": cell,
        "mesh": mesh_name,
        "n_devices": int(mesh.devices.size),
        "kind": built.kind,
        "meta": built.meta,
        "compile_s": round(t1 - t0, 1),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_device_bytes": (mem.argument_size_in_bytes
                                  + mem.temp_size_in_bytes
                                  + mem.output_size_in_bytes
                                  - mem.alias_size_in_bytes),
        },
        "cost": {
            # trip-count-aware totals from the HLO walk (XLA's own
            # cost_analysis counts while bodies once; kept for reference)
            "flops_per_device": hlo.flops,
            "bytes_per_device": hlo.bytes,
            "xla_flops_one_trip": xla_cost.get("flops", 0.0),
            "xla_bytes_one_trip": xla_cost.get("bytes accessed", 0.0),
        },
        "collectives": hlo.summary(),
        "analytic_bytes": analytic_bytes(built),
        "while_trips": hlo.while_trips[:40],
    }
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"_{tag}" if tag else ""
        path = os.path.join(
            out_dir, f"{arch}_{cell}_{rec['mesh']}{suffix}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print(f"[dryrun] {arch} x {cell} x {rec['mesh']}: "
          f"compile {rec['compile_s']}s, "
          f"peak/device {rec['memory']['peak_device_bytes']/2**30:.2f} GiB "
          f"(state {rec['analytic_bytes']['total']/2**30:.2f}), "
          f"{rec['cost']['flops_per_device']/1e9:.1f} GFLOP/device, "
          f"link {hlo.link_bytes/2**20:.1f} MiB/device")
    print("  memory_analysis:", mem)
    print("  cost_analysis: flops=%.3e bytes=%.3e" % (
        rec["cost"]["flops_per_device"], rec["cost"]["bytes_per_device"]))
    return rec


def iter_cells():
    for arch in all_arch_names():
        cfg = get_config(arch)
        for cell in SHAPES:
            ok, why = cell_supported(cfg, cell)
            yield arch, cell, ok, why


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--cell", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--continue-on-error", action="store_true")
    args = ap.parse_args()

    results, failures = [], []
    if args.all:
        for arch, cell, ok, why in iter_cells():
            if not ok:
                print(f"[dryrun] SKIP {arch} x {cell}: {why}")
                results.append({"arch": arch, "cell": cell, "skipped": why})
                continue
            try:
                results.append(run_cell(arch, cell, args.multi_pod,
                                        args.out, args.microbatches))
            except Exception as e:  # noqa: BLE001
                traceback.print_exc()
                failures.append((arch, cell, str(e)))
                if not args.continue_on_error:
                    raise
    else:
        run_cell(args.arch, args.cell, args.multi_pod, args.out,
                 args.microbatches)
    if failures:
        print(f"[dryrun] {len(failures)} FAILURES:", failures)
        raise SystemExit(1)
    print(f"[dryrun] complete: {len(results)} cells")


if __name__ == "__main__":
    main()
