"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --smoke \
        --steps 200 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt --ckpt-every 50

Features exercised here (and by tests/test_fault.py):
  * periodic async sharded checkpoints,
  * restart/resume from the latest checkpoint (--resume),
  * injected node failures (--fail-at N) with supervisor restart,
  * injected stragglers (--straggle-at N) and z-score detection,
  * elastic restore onto a different mesh (--data/--model flags may differ
    between runs; restore re-device_puts onto the current mesh).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import init_params, param_axes, abstract_params
from repro.optim import AdamW, cosine_schedule
from repro.parallel.sharding import make_param_shardings
from repro.train import (FaultConfig, StragglerDetector, latest_step,
                         make_train_step, restore_checkpoint,
                         save_checkpoint, simulate_failures)
from repro.train.fault import InjectedFailure, run_with_recovery
from repro.train.train_step import TrainState, init_train_state


def build(args):
    cfg = get_config(args.arch, smoke=args.smoke)
    try:
        mesh = make_host_mesh(data=args.data, model=args.model)
    except ValueError:
        mesh = None
    opt = AdamW(lr=cosine_schedule(args.lr, args.warmup, args.steps))
    step_fn = make_train_step(cfg, opt, microbatches=args.microbatches)
    return cfg, mesh, opt, step_fn


def run(args, resume_signal=None) -> int:
    cfg, mesh, opt, step_fn = build(args)
    ds = SyntheticLM(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch,
                     seed=args.seed)
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    state = init_train_state(params, opt)
    start = 0

    shardings = None
    if mesh is not None:
        ap = abstract_params(cfg)
        param_sh, _ = make_param_shardings(mesh, param_axes(cfg), ap)
        shardings = TrainState(params=param_sh,
                               opt=type(state.opt)(
                                   step=None, m=param_sh, v=param_sh),
                               residual=None)
        state = jax.device_put(state, shardings)

    if (args.resume or resume_signal is not None) and args.ckpt_dir:
        last = latest_step(args.ckpt_dir)
        if last is not None:
            tgt = jax.eval_shape(lambda: state)
            state, meta = restore_checkpoint(args.ckpt_dir, last, tgt,
                                             shardings)
            start = last
            print(f"[train] resumed from step {last}")

    jit_step = jax.jit(step_fn, donate_argnums=0)
    det = StragglerDetector(z_threshold=args.z_threshold)
    fcfg = FaultConfig(fail_at_steps=tuple(args.fail_at),
                       straggle_at_steps=tuple(args.straggle_at))
    pending_save = None
    for i in range(start, args.steps):
        t0 = time.time()
        simulate_failures(i, fcfg)
        batch = ds.batch_at(i)
        state, metrics = jit_step(state, batch)
        if i % args.log_every == 0:
            print(f"[train] step {i} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
        dt = time.time() - t0
        if det.observe(i, dt):
            print(f"[train] STRAGGLER step {i}: {dt*1e3:.0f} ms")
        if args.ckpt_dir and args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            if pending_save is not None:
                pending_save.join()
            pending_save = save_checkpoint(
                args.ckpt_dir, i + 1, state,
                metadata={"arch": args.arch, "loss": float(metrics["loss"])},
                async_save=True)
    if pending_save is not None:
        pending_save.join()
    if det.flagged:
        print(f"[train] stragglers flagged: {[s for s, _, _ in det.flagged]}")
    return args.steps


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[])
    ap.add_argument("--straggle-at", type=int, nargs="*", default=[])
    ap.add_argument("--z-threshold", type=float, default=3.0)
    ap.add_argument("--max-restarts", type=int, default=3)
    args = ap.parse_args()

    if args.fail_at:
        fail_seq = [tuple(args.fail_at)]

        def attempt(resume):
            # after the first failure the injection list is cleared
            if resume is not None:
                args.fail_at = []
                args.resume = True
            return run(args, resume)

        final = run_with_recovery(attempt, max_restarts=args.max_restarts)
    else:
        final = run(args)
    print(f"[train] done at step {final}")


if __name__ == "__main__":
    main()
