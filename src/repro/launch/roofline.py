"""Roofline analysis over dry-run records.

Per (arch x cell x mesh):
    compute_s    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16)
    memory_s     = HLO_bytes_per_device / HBM_bw              (819 GB/s)
    collective_s = link_bytes_per_device / ICI_bw             (50 GB/s/link)

plus MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), N_active for MoE,
and the useful-compute ratio MODEL_FLOPS / (HLO_FLOPs x chips) that
surfaces remat/recompute/padding waste.

Usage:
    PYTHONPATH=src python -m repro.launch.roofline --in experiments/dryrun \
        --out EXPERIMENTS_roofline.md
"""

from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.models import count_params

PEAK_FLOPS = 197e12          # TPU v5e bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_SUGGEST = {
    "compute": "raise MXU utilization: larger per-device batch/microbatch, "
               "fuse attention (banded/pallas path) to cut masked-FLOP waste",
    "memory": "cut HBM traffic: bf16 activations end-to-end, fuse "
              "elementwise chains, reuse KV layout to avoid transposes",
    "collective": "cut link traffic: shard so the hot dim stays local, "
                  "overlap collectives with compute, int8-compress the "
                  "DCN (pod) hop",
}


def model_flops(arch: str, cell: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[cell]
    n = count_params(cfg)
    if cfg.n_experts:
        # active = non-expert params + activated fraction of expert params
        expert_frac = (cfg.top_k + (1 if cfg.shared_expert else 0)) \
            / (cfg.n_experts + (1 if cfg.shared_expert else 0))
        expert_params = (cfg.n_layers * cfg.n_experts *
                         (3 if True else 2) * cfg.d_model * cfg.d_ff)
        shared = (cfg.n_layers * 3 * cfg.d_model * cfg.d_ff
                  if cfg.shared_expert else 0)
        n = n - expert_params - shared + \
            (expert_params + shared) * expert_frac
    if spec["kind"] == "train":
        tokens = spec["batch"] * spec["seq"]
        if cfg.is_encdec:
            tokens = spec["batch"] * (cfg.dec_max + cfg.enc_seq)
        return 6.0 * n * tokens
    if spec["kind"] == "prefill":
        tokens = spec["batch"] * (cfg.dec_max + cfg.enc_seq
                                  if cfg.is_encdec else spec["seq"])
        return 2.0 * n * tokens
    # decode: one token per slot
    return 2.0 * n * spec["batch"]


def analytic_traffic(arch: str, cell: str, chips: int, meta: dict) -> float:
    """Structural per-device HBM traffic (bytes/step): the memory-term
    model.  The op-level HLO byte count on this CPU backend over-bills
    (CPU fuses far less than TPU, bf16 legalizes through f32), so the
    roofline memory term uses this documented model; the HLO number is
    reported alongside as the pessimistic bound.

    train:  optimizer sweep (read p,m,v + write p,m,v, fp32) + bf16 cast
            write + per-(microbatch x layer) activation I/O with
            c_act = 24 tensor-passes of [B_mb, S, d_model] (fwd ~8 reads+
            writes of the residual-sized tensors, bwd ~2x, remat ~1x)
            + logits fp32 (3 passes) + kv stream per layer.
    prefill: weights once (bf16) + single-pass activations (c=8)
            + cache write.
    decode: weights once + full cache read + slice write (the classic
            bandwidth-bound decode model).
    """
    cfg = get_config(arch)
    spec = SHAPES[cell]
    N = count_params(cfg)
    kind = spec["kind"]
    B, S = spec["batch"], spec["seq"]
    if cfg.is_encdec:
        S = cfg.dec_max + cfg.enc_seq
    L, d = cfg.n_layers + cfg.n_enc_layers, cfg.d_model
    mesh = meta.get("mesh", {})
    dp = mesh.get("data", 16) * mesh.get("pod", 1)
    model_n = mesh.get("model", 16)

    # MoE: only activated expert weights stream per token pass
    n_active = N
    if cfg.n_experts:
        expert_params = cfg.n_layers * cfg.n_experts * 3 * cfg.d_model \
            * cfg.d_ff
        shared = (cfg.n_layers * 3 * cfg.d_model * cfg.d_ff
                  if cfg.shared_expert else 0)
        frac = (cfg.top_k + (1 if cfg.shared_expert else 0)) / (
            cfg.n_experts + (1 if cfg.shared_expert else 0))
        n_active = N - expert_params - shared + (expert_params + shared) \
            * frac

    if kind == "train":
        M = meta.get("microbatches", 8)
        B_loc = max(1, B // dp)
        opt = 6 * 4 * N / chips                      # p,m,v fp32 r+w
        cast = (4 + 2) * N / chips                   # fp32 read, bf16 write
        # activated weights re-streamed per microbatch (bf16, fwd+bwd+remat)
        wstream = 3 * 2 * n_active * M / chips
        acts = 24 * L * B_loc * S * d * 2
        logits = 3 * 4 * B_loc * S * cfg.vocab / max(1, model_n)
        kv = 3 * 2 * 2 * L * B_loc * S * cfg.n_kv_heads * cfg.d_head
        return opt + cast + wstream + acts + logits + kv

    if kind == "prefill":
        B_loc = max(1, B // dp)
        w = 2 * n_active / chips                     # bf16 weights, one pass
        acts = 8 * L * B_loc * S * d * 2
        cache = 2 * 2 * L * B_loc * S * cfg.n_kv_heads * cfg.d_head
        return w + acts + cache

    # decode: weights once + cache read + slice write
    w = 2 * n_active / (model_n if B >= dp else chips)
    cache_total = 2 * 2 * L * B * S * cfg.n_kv_heads * cfg.d_head
    if cfg.family == "ssm":
        cache_total = 2 * cfg.n_layers * B * cfg.d_model * 66   # wkv state
    elif cfg.family == "hybrid":
        n_attn = sum(1 for k in cfg.block_pattern if k == "local")
        cache_total = 2 * 2 * cfg.n_layers * (
            n_attn / len(cfg.block_pattern)) * B * min(cfg.window or S, S) \
            * cfg.n_kv_heads * cfg.d_head
    elif cfg.window and "local" in cfg.block_pattern:
        # gemma3: 5-of-6 layers read only their window
        n_local = sum(1 for k in cfg.block_pattern if k == "local")
        n_glob = len(cfg.block_pattern) - n_local
        eff = (n_local * min(cfg.window, S) + n_glob * S) / (
            len(cfg.block_pattern) * S)
        cache_total *= eff
    return w + cache_total / chips


def analyse_record(rec: dict) -> dict:
    flops = rec["cost"]["flops_per_device"]
    hlo_bytes = rec["cost"]["bytes_per_device"]
    link = rec["collectives"]["link_bytes"]
    chips = rec["n_devices"]
    mem_bytes = analytic_traffic(rec["arch"], rec["cell"], chips,
                                 rec.get("meta", {}))
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "collective_s": link / ICI_BW,
        "hlo_bytes_bound_s": hlo_bytes / HBM_BW,
    }
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=terms.get)
    mf = model_flops(rec["arch"], rec["cell"])
    hlo_total = flops * chips
    useful = mf / hlo_total if hlo_total else 0.0
    bound = max(terms["compute_s"], terms["memory_s"],
                terms["collective_s"])
    # roofline fraction: model-useful work per second at the bound vs peak
    step_s = bound
    achieved = mf / chips / step_s if step_s else 0.0
    return dict(
        rec,
        terms=terms,
        dominant=dominant.replace("_s", ""),
        model_flops=mf,
        useful_ratio=useful,
        step_time_bound_s=step_s,
        roofline_frac=achieved / PEAK_FLOPS,
        suggestion=_SUGGEST[dominant.replace("_s", "")],
    )


def to_markdown(rows: list) -> str:
    hdr = ("| arch | cell | mesh | compute | memory | collective | "
           "bound | state GiB/dev | useful | roofline |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("skipped"):
            out.append(f"| {r['arch']} | {r['cell']} | — | — | — | — | "
                       f"skip: {r['skipped']} | — | — | — |\n")
            continue
        t = r["terms"]
        state = r.get("analytic_bytes", {}).get(
            "total", r["memory"]["peak_device_bytes"])
        out.append(
            f"| {r['arch']} | {r['cell']} | {r['mesh']} "
            f"| {t['compute_s']*1e3:.1f} ms | {t['memory_s']*1e3:.1f} ms "
            f"| {t['collective_s']*1e3:.1f} ms | **{r['dominant']}** "
            f"| {state/2**30:.2f} "
            f"| {r['useful_ratio']:.2f} | {r['roofline_frac']*100:.1f}% |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="in_dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline.md")
    ap.add_argument("--json-out", default="experiments/roofline.json")
    args = ap.parse_args()

    rows = []
    for path in sorted(glob.glob(os.path.join(args.in_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("kind") == "simulate":
            continue       # flywire SNN records carry their own analysis
        rows.append(analyse_record(rec))
    md = to_markdown(rows)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        f.write(md)
    with open(args.json_out, "w") as f:
        json.dump(rows, f, indent=1)
    print(md)


if __name__ == "__main__":
    main()
