"""GPipe-style pipeline parallelism over a "stage" mesh axis.

Each device owns one stage's params; microbatches stream through the
stages via collective_permute (ppermute), M + S - 1 ticks for M
microbatches over S stages (bubble fraction (S-1)/(M+S-1)).

The schedule runs under shard_map on a real mesh or under vmap with an
axis name (tests).  It is the optional PP axis for the LM stack — the
production mesh uses DP x TP (+ pod DP); PP composes by replacing the
layer scan with stage-sharded sub-stacks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def pipeline_schedule(stage_fn, params_local, xs, *, axis: str,
                      n_stages: int):
    """Runs inside shard_map/vmap.  params_local: this stage's params;
    xs: [M, ...] microbatches (same on every stage; only stage 0 reads
    them).  Returns [M, ...] outputs (valid on the last stage, zeros
    elsewhere — callers psum or read the last stage's shard)."""
    S = n_stages
    M = xs.shape[0]
    stage = jax.lax.axis_index(axis)
    mb_shape = xs.shape[1:]

    # cyclic shift: S-1 -> 0 wraps harmlessly (stage 0 ignores its recv);
    # a full permutation is required by vmap's ppermute batching rule
    perm = [(i, (i + 1) % S) for i in range(S)]

    def tick(t, carry):
        recv, outs = carry
        ingest = jnp.where(t < M, jnp.minimum(t, M - 1), 0)
        x0 = xs[ingest]
        x = jnp.where(stage == 0, x0, recv)
        y = stage_fn(params_local, x)
        recv_next = jax.lax.ppermute(y, axis, perm)
        out_t = jnp.clip(t - (S - 1), 0, M - 1)
        emit = jnp.logical_and(stage == S - 1, t >= S - 1)
        outs = outs.at[out_t].set(jnp.where(emit, y, outs[out_t]))
        return recv_next, outs

    recv0 = jnp.zeros(mb_shape, xs.dtype)
    outs0 = jnp.zeros((M,) + mb_shape, xs.dtype)
    _, outs = jax.lax.fori_loop(0, M + S - 1, tick, (recv0, outs0))
    return outs


def pipeline_apply_emulated(stage_fn, stage_params, xs, n_stages: int):
    """vmap-emulated pipeline (single device): stage_params leaves
    [S, ...]; xs [M, ...].  Returns [M, ...] from the last stage."""
    axis = "stage"

    def per_stage(params_local):
        return pipeline_schedule(stage_fn, params_local, xs, axis=axis,
                                 n_stages=n_stages)

    outs = jax.vmap(per_stage, axis_name=axis)(stage_params)
    return outs[-1]            # last stage holds the real outputs


def pipeline_apply(stage_fn, stage_params, xs, mesh, n_stages: int,
                   axis: str = "stage"):
    """shard_map pipeline on a real mesh with a `stage` axis."""
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    def shard_fn(params, xs_all):
        params = jax.tree.map(lambda a: a[0], params)
        outs = pipeline_schedule(stage_fn, params, xs_all, axis=axis,
                                 n_stages=n_stages)
        # deliver outputs everywhere (tests read them host-side)
        stage = jax.lax.axis_index(axis)
        outs = jnp.where(stage == n_stages - 1, outs, 0)
        return jax.lax.psum(outs, axis)

    spec_p = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(shard_fn, mesh=mesh, in_specs=(spec_p, P()),
                   out_specs=P(), check_rep=False)
    return jax.jit(fn)(stage_params, xs)
