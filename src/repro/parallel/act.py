"""Activation sharding constraints.

GSPMD propagation from parameter shardings alone goes badly wrong inside
scan-of-remat bodies (observed: involuntary full rematerialization
replicating [B,H,S,chunk] attention tensors on the 256-way mesh).  The fix
is the standard one: pin the residual stream / logits / attention layouts
at block boundaries with with_sharding_constraint.

The policy is process-global and set by the launcher (build_step) before
lowering; model code calls ``shard_act(x, name)`` which is a no-op when no
policy is installed (tests, single-device runs).

Names used by the model stack:
  residual   [B, S, D]    — batch over data axes (seq over "model" when
                            sequence parallelism is enabled)
  logits     [B, S, V]    — vocab over "model"
  heads      [B, H, S, D] — attention heads over "model"
"""

from __future__ import annotations

import contextlib
from typing import Optional

import jax

_POLICY: dict = {}


def set_policy(policy: dict) -> None:
    global _POLICY
    _POLICY = dict(policy)


def get_policy() -> dict:
    return dict(_POLICY)


def clear_policy() -> None:
    global _POLICY
    _POLICY = {}


@contextlib.contextmanager
def policy(p: dict):
    old = get_policy()
    set_policy(p)
    try:
        yield
    finally:
        set_policy(old)


def shard_act(x, name: str):
    spec = _POLICY.get(name)
    if spec is None:
        return x
    try:
        if len(spec) > x.ndim:
            return x
    except TypeError:
        pass
    return jax.lax.with_sharding_constraint(x, spec)
