from .sharding import (ShardingRules, DEFAULT_RULES, make_param_shardings,
                       batch_spec, logical_to_spec, solve_rules)

__all__ = ["ShardingRules", "DEFAULT_RULES", "make_param_shardings",
           "batch_spec", "logical_to_spec", "solve_rules"]
