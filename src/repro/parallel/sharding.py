"""Logical-axis -> mesh-axis sharding rules with a divisibility-aware solver.

The model stack annotates every parameter dim with a logical name
("embed", "heads", "mlp", "vocab", "experts", ...).  The solver maps those
names to mesh axes per architecture:

  * tensor-parallel names (heads/mlp/vocab/experts) go to "model";
  * "embed" is FSDP-sharded over "data" (ZeRO-3 via GSPMD: XLA inserts the
    per-layer all-gathers) — and over ("pod","data") in the multi-pod mesh;
  * a dim whose size does not divide its mesh-axis extent falls back to
    replication for that dim (GSPMD would otherwise pad); the solver
    records every fallback so the roofline "useful FLOPs" ratio can call
    out the waste.

The same rules translate activation logical specs (batch/seq) for inputs.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    rules: tuple            # ((logical_name, mesh_axis_or_tuple), ...)
    fsdp: bool = True       # shard "embed" over the data axes

    def as_dict(self):
        return dict(self.rules)


DEFAULT_RULES = ShardingRules(rules=(
    ("vocab", "model"),
    ("heads", "model"),
    ("kv_heads", "model"),
    ("mlp", "model"),
    ("experts", "model"),
    ("embed", "data"),       # FSDP; replaced by ("pod","data") when multi-pod
    ("layers", None),
))


def _mesh_axes_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _axis_size(mesh_sizes, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return int(np.prod([mesh_sizes[a] for a in axis]))
    return mesh_sizes[axis]


def solve_rules(mesh: Mesh, rules: ShardingRules = DEFAULT_RULES
                ) -> ShardingRules:
    """Adapt the default rules to the mesh (e.g. extend FSDP over the pod
    axis when present)."""
    sizes = _mesh_axes_sizes(mesh)
    out = []
    for name, ax in rules.rules:
        if name == "embed" and rules.fsdp:
            ax = (("pod", "data") if "pod" in sizes else "data")
        out.append((name, ax))
    return ShardingRules(rules=tuple(out), fsdp=rules.fsdp)


def logical_to_spec(axes: tuple, mesh: Mesh, rules: ShardingRules,
                    dims: Optional[tuple] = None,
                    fallbacks: Optional[list] = None) -> P:
    """One param's logical axes (+ dim sizes for divisibility checks) -> P."""
    table = rules.as_dict()
    sizes = _mesh_axes_sizes(mesh)
    used = set()
    parts = []
    for i, name in enumerate(axes):
        ax = table.get(name)
        if ax is None:
            parts.append(None)
            continue
        key = tuple(ax) if isinstance(ax, (tuple, list)) else (ax,)
        if any(a in used for a in key):
            parts.append(None)          # each mesh axis used at most once
            continue
        n = _axis_size(sizes, ax)
        if dims is not None and dims[i] % n != 0:
            if fallbacks is not None:
                fallbacks.append((name, dims[i], ax, n))
            parts.append(None)
            continue
        used.update(key)
        parts.append(ax)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def make_param_shardings(mesh: Mesh, param_axes_tree, abstract_tree,
                         rules: Optional[ShardingRules] = None):
    """(axes tree, abstract value tree) -> (NamedSharding tree, fallbacks)."""
    rules = solve_rules(mesh, rules or DEFAULT_RULES)
    fallbacks: list = []

    def one(axes, aval):
        spec = logical_to_spec(tuple(axes), mesh, rules, tuple(aval.shape),
                               fallbacks)
        return NamedSharding(mesh, spec)

    def is_axes_leaf(x):
        return (isinstance(x, tuple) and len(x) > 0
                and all(isinstance(a, str) or a is None for a in x))

    shardings = jax.tree.map(one, param_axes_tree, abstract_tree,
                             is_leaf=is_axes_leaf)
    return shardings, fallbacks


def batch_spec(mesh: Mesh, ndim: int, batch_divisible: bool = True) -> P:
    """Shard the leading (batch) dim over all data-parallel axes."""
    sizes = _mesh_axes_sizes(mesh)
    dp = tuple(a for a in ("pod", "data") if a in sizes)
    if not dp or not batch_divisible:
        return P(*([None] * ndim))
    return P(dp, *([None] * (ndim - 1)))


def data_axis_size(mesh: Mesh) -> int:
    sizes = _mesh_axes_sizes(mesh)
    return int(np.prod([sizes[a] for a in ("pod", "data") if a in sizes]))
