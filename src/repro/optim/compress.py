"""Int8 gradient compression with error feedback — applied to the pod (DCN)
axis only, where link bandwidth is ~50x scarcer than in-pod ICI.

Numerics path (verified in tests): per-tensor symmetric int8 quantization,
error-feedback residual accumulation (the quantization error is carried to
the next step so the compressed SGD trajectory stays unbiased in the
Karimireddy et al. sense).

Collective path: ``compressed_psum`` — a shard_map-compatible hierarchical
reduction: full-precision psum over the in-pod ("data") axis first, then
int8 quantize -> psum over the "pod" axis -> dequantize.  DCN traffic drops
4x (f32->i8); the sum-of-quantized ordering is what a real int8 DCN
allreduce would produce.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_int8(x):
    """Returns (q int8, scale f32 scalar per tensor)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def decompress_int8(q, scale):
    return q.astype(jnp.float32) * scale


def error_feedback_update(grad, residual):
    """(grad + residual) -> (compressed-then-decompressed grad, new residual)."""
    g = grad.astype(jnp.float32) + residual
    q, s = compress_int8(g)
    g_hat = decompress_int8(q, s)
    return g_hat, g - g_hat


def compressed_psum(x, *, pod_axis: str, data_axis: str | None = None):
    """Hierarchical reduction for use inside shard_map:
    fp32 psum in-pod, int8 psum across pods.

    A scalar pmax first agrees on a shared quantization scale across pods
    (one f32 per tensor on the wire), then the int8 payloads are summed and
    dequantized with that shared scale — the ordering a real int8 DCN
    allreduce uses."""
    if data_axis is not None:
        x = jax.lax.psum(x, data_axis)
    xf = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(xf)), pod_axis)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int32), pod_axis)
    return q_sum.astype(jnp.float32) * scale
