"""AdamW with cosine schedule.  State dtypes configurable (fp32 default;
bf16 m/v is the memory-pressure option used by the biggest configs — the
trade-off is documented in EXPERIMENTS.md §Dry-run)."""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup),
                        0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: Any = jnp.float32
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.state_dtype)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        # global-norm clip
        gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                          for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gn, 1e-12))

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m_new = self.b1 * m.astype(jnp.float32) + (1 - self.b1) * g
            v_new = self.b2 * v.astype(jnp.float32) + (1 - self.b2) * g * g
            mh = m_new / (1 - self.b1 ** step.astype(jnp.float32))
            vh = v_new / (1 - self.b2 ** step.astype(jnp.float32))
            delta = mh / (jnp.sqrt(vh) + self.eps)
            delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (-(lr * delta)).astype(p.dtype), \
                m_new.astype(self.state_dtype), v_new.astype(self.state_dtype)

        flat_g, tdef = jax.tree.flatten(grads)
        flat_m = tdef.flatten_up_to(state.m)
        flat_v = tdef.flatten_up_to(state.v)
        flat_p = tdef.flatten_up_to(params)
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        updates = tdef.unflatten([o[0] for o in out])
        m = tdef.unflatten([o[1] for o in out])
        v = tdef.unflatten([o[2] for o in out])
        return updates, AdamWState(step=step, m=m, v=v), gn

    @staticmethod
    def apply_updates(params, updates):
        return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params,
                            updates)
