from .adamw import AdamW, cosine_schedule
from .compress import (compress_int8, decompress_int8, compressed_psum,
                       error_feedback_update)

__all__ = ["AdamW", "cosine_schedule", "compress_int8", "decompress_int8",
           "compressed_psum", "error_feedback_update"]
