from .ops import lif_update, lif_update_fx
from .ref import lif_update_ref, lif_update_fx_ref

__all__ = ["lif_update", "lif_update_fx", "lif_update_ref", "lif_update_fx_ref"]
