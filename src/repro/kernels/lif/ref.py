"""Pure-jnp oracle for the fused LIF kernel — delegates to the core neuron
math (the same functions Brian2-parity is validated against), reshaped to the
kernel's [rows, 128] layout."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.neuron import LIFParams, LIFState, lif_step, lif_step_fx


def lif_update_ref(v, g, refrac, g_in, v_in, force, *, params: LIFParams):
    shape = v.shape
    st = LIFState(v=v.reshape(-1), g=g.reshape(-1), refrac=refrac.reshape(-1))
    new, spk = lif_step(st, g_in.reshape(-1), params, v_in.reshape(-1),
                        force.reshape(-1) != 0)
    return (new.v.reshape(shape), new.g.reshape(shape),
            new.refrac.reshape(shape), spk.astype(jnp.int32).reshape(shape))


def lif_update_fx_ref(v, g, refrac, g_in, v_in, force, *, params: LIFParams):
    shape = v.shape
    st = LIFState(v=v.reshape(-1), g=g.reshape(-1), refrac=refrac.reshape(-1))
    new, spk = lif_step_fx(st, g_in.reshape(-1), params, v_in.reshape(-1),
                           force.reshape(-1) != 0)
    return (new.v.reshape(shape), new.g.reshape(shape),
            new.refrac.reshape(shape), spk.astype(jnp.int32).reshape(shape))
