"""Fused LIF neuron-update Pallas kernel (TPU target, VPU elementwise).

One kernel step fuses what the jnp path does in ~10 separate HLO ops:
synaptic-input accumulate, forward-Euler membrane/conductance update,
threshold compare, reset, refractory countdown, and spike emission — the
per-timestep neuron program of the paper's Loihi 2 microcode, as a TPU
vector kernel.

Layout: neurons are viewed as [rows, 128] (128 = TPU lane width); the grid
tiles rows in blocks of ``BLK_ROWS`` sublanes.  All operands live in VMEM.

Float32 and int32 fixed-point (Q19.12, Loihi-analogue) variants share the
structure; coefficients arrive via closure as compile-time constants, exactly
like Loihi microcode "user-defined constants".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.neuron import FX_FRAC_BITS, LIFParams

BLK_ROWS = 8          # sublane tile
LANES = 128           # lane width


def _lif_body_f32(v_ref, g_ref, ref_ref, gin_ref, vin_ref, force_ref,
                  v_out, g_out, refr_out, spk_out, *, alpha_m, decay_g,
                  v0, v_r, v_th, ref_steps):
    v = v_ref[...]
    g = g_ref[...]
    refrac = ref_ref[...]
    active = refrac <= 0
    g = jnp.where(active, g + gin_ref[...], g)
    v = jnp.where(active, v + vin_ref[...], v)
    v = jnp.where(active, v + alpha_m * (v0 - v + g), v)
    g = jnp.where(active, g * decay_g, g)
    spikes = jnp.logical_and(active, v > v_th)
    spikes = jnp.logical_or(spikes, jnp.logical_and(active,
                                                    force_ref[...] != 0))
    v = jnp.where(spikes, v_r, v)
    g = jnp.where(spikes, 0.0, g)
    refrac = jnp.where(spikes, ref_steps,
                       jnp.maximum(refrac - 1, 0)).astype(jnp.int32)
    v_out[...] = v
    g_out[...] = g
    refr_out[...] = refrac
    spk_out[...] = spikes.astype(jnp.int32)


def _lif_body_fx(v_ref, g_ref, ref_ref, gin_ref, vin_ref, force_ref,
                 v_out, g_out, refr_out, spk_out, *, fx_alpha_m16,
                 fx_gdecay16, fx_v0, fx_v_r, fx_v_th, ref_steps):
    v = v_ref[...]
    g = g_ref[...]
    refrac = ref_ref[...]
    active = refrac <= 0
    g = jnp.where(active, g + (gin_ref[...] << FX_FRAC_BITS), g)
    v = jnp.where(active, v + (vin_ref[...] << FX_FRAC_BITS), v)
    # 16-bit coefficients via the narrow-multiplier form (see core.neuron)
    dv = (((fx_v0 - v + g) >> 2) * fx_alpha_m16) >> 14
    v = jnp.where(active, v + dv, v)
    g = jnp.where(active, g - (((g >> 2) * fx_gdecay16) >> 14), g)
    spikes = jnp.logical_and(active, v > fx_v_th)
    spikes = jnp.logical_or(spikes, jnp.logical_and(active,
                                                    force_ref[...] != 0))
    v = jnp.where(spikes, fx_v_r, v)
    g = jnp.where(spikes, 0, g)
    refrac = jnp.where(spikes, ref_steps,
                       jnp.maximum(refrac - 1, 0)).astype(jnp.int32)
    v_out[...] = v
    g_out[...] = g
    refr_out[...] = refrac
    spk_out[...] = spikes.astype(jnp.int32)


def _pallas_lif(v, g, refrac, g_in, v_in, force, body, out_dtype,
                interpret: bool):
    rows = v.shape[0]
    blk = min(BLK_ROWS, rows)
    grid = (pl.cdiv(rows, blk),)
    spec = pl.BlockSpec((blk, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[spec] * 6,
        out_specs=[spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct(v.shape, out_dtype),
            jax.ShapeDtypeStruct(v.shape, out_dtype),
            jax.ShapeDtypeStruct(v.shape, jnp.int32),
            jax.ShapeDtypeStruct(v.shape, jnp.int32),
        ],
        interpret=interpret,
    )(v, g, refrac, g_in, v_in, force)


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def lif_update_f32(v, g, refrac, g_in, v_in, force, *, params: LIFParams,
                   interpret: bool = True):
    """All args [rows, 128] float32 (refrac/force int32)."""
    body = functools.partial(
        _lif_body_f32, alpha_m=params.alpha_m, decay_g=params.decay_g,
        v0=params.v0, v_r=params.v_r, v_th=params.v_th,
        ref_steps=params.ref_steps)
    return _pallas_lif(v, g, refrac, g_in, v_in, force, body, jnp.float32,
                       interpret)


@functools.partial(jax.jit, static_argnames=("params", "interpret"))
def lif_update_fx32(v, g, refrac, g_in, v_in, force, *, params: LIFParams,
                    interpret: bool = True):
    """Fixed-point variant; v/g int32 Q19.12, g_in/v_in raw weight units."""
    body = functools.partial(
        _lif_body_fx, fx_alpha_m16=params.fx_alpha_m16,
        fx_gdecay16=params.fx_gdecay16, fx_v0=params.fx_v0,
        fx_v_r=params.fx_v_r, fx_v_th=params.fx_v_th,
        ref_steps=params.ref_steps)
    return _pallas_lif(v, g, refrac, g_in, v_in, force, body, jnp.int32,
                       interpret)
