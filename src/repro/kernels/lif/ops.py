"""Public jit'd wrappers for the fused LIF kernel.

Handles padding to the [rows, 128] kernel layout from flat [n] state and
dispatches to the float32 or fixed-point kernel.  ``interpret=True`` (the
default in this CPU container) runs the kernel body in the Pallas
interpreter; on TPU pass ``interpret=False``.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.neuron import LIFParams, LIFState
from .kernel import LANES, lif_update_f32, lif_update_fx32


def _to_tiles(x, n_pad, dtype):
    x = jnp.asarray(x, dtype)
    x = jnp.pad(x, (0, n_pad - x.shape[0]))
    return x.reshape(-1, LANES)


def lif_update(state: LIFState, g_in, params: LIFParams, v_in=None,
               force=None, interpret: bool = True):
    """Flat [n] fused update, float path.  Returns (LIFState, spikes bool[n])."""
    n = state.v.shape[0]
    n_pad = ((n + LANES - 1) // LANES) * LANES
    zeros_f = jnp.zeros(n, jnp.float32)
    zeros_i = jnp.zeros(n, jnp.int32)
    args = [_to_tiles(state.v, n_pad, jnp.float32),
            _to_tiles(state.g, n_pad, jnp.float32),
            _to_tiles(state.refrac, n_pad, jnp.int32),
            _to_tiles(g_in, n_pad, jnp.float32),
            _to_tiles(v_in if v_in is not None else zeros_f, n_pad,
                      jnp.float32),
            _to_tiles(force.astype(jnp.int32) if force is not None
                      else zeros_i, n_pad, jnp.int32)]
    v, g, refrac, spk = lif_update_f32(*args, params=params,
                                       interpret=interpret)
    st = LIFState(v=v.reshape(-1)[:n], g=g.reshape(-1)[:n],
                  refrac=refrac.reshape(-1)[:n])
    return st, (spk.reshape(-1)[:n] != 0)


def lif_update_fx(state: LIFState, g_in_units, params: LIFParams,
                  v_in_units=None, force=None, interpret: bool = True):
    """Flat [n] fused update, int32 fixed-point path."""
    n = state.v.shape[0]
    n_pad = ((n + LANES - 1) // LANES) * LANES
    zeros_i = jnp.zeros(n, jnp.int32)
    args = [_to_tiles(state.v, n_pad, jnp.int32),
            _to_tiles(state.g, n_pad, jnp.int32),
            _to_tiles(state.refrac, n_pad, jnp.int32),
            _to_tiles(g_in_units, n_pad, jnp.int32),
            _to_tiles(v_in_units if v_in_units is not None else zeros_i,
                      n_pad, jnp.int32),
            _to_tiles(force.astype(jnp.int32) if force is not None
                      else zeros_i, n_pad, jnp.int32)]
    v, g, refrac, spk = lif_update_fx32(*args, params=params,
                                        interpret=interpret)
    st = LIFState(v=v.reshape(-1)[:n], g=g.reshape(-1)[:n],
                  refrac=refrac.reshape(-1)[:n])
    return st, (spk.reshape(-1)[:n] != 0)
