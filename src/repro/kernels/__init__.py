"""Pallas TPU kernels for the compute hot-spots (validated in interpret
mode on CPU; pass interpret=False on real TPU):

* lif/             fused LIF neuron update (float32 + int32 fixed-point)
* spike_prop/      block-gated synaptic delivery (the paper's event-driven
                   hotspot, TPU-adapted as tile-granular activity gating)
* flash_attention/ online-softmax attention with causal/local masks
                   (LM-stack prefill hotspot; local-window block culling)
"""
