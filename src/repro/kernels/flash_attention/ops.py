"""Jit'd GQA-aware wrapper for the flash attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import flash_attention_pallas


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "interpret", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    interpret=True, bq=128, bk=128):
    """q: [B, H, Sq, D]; k, v: [B, Hkv, Skv, D] with H % Hkv == 0 (GQA).

    window: sliding-window size (keys within [i-window, i]); None = full.
    Returns [B, H, Sq, D] in q.dtype.
    """
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    groups = H // Hkv
    if scale is None:
        scale = D ** -0.5

    # GQA expansion: repeat kv heads per group (kernel sees flat BH)
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)

    bq_, bk_ = min(bq, Sq), min(bk, Skv)
    pad_q = (-Sq) % bq_
    pad_k = (-Skv) % bk_
    qf = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0))).reshape(
        B * H, Sq + pad_q, D)
    kf = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0))).reshape(
        B * H, Skv + pad_k, D)
    vf = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0))).reshape(
        B * H, Skv + pad_k, D)

    out = flash_attention_pallas(
        qf.astype(jnp.float32), kf.astype(jnp.float32),
        vf.astype(jnp.float32), scale=scale, causal=causal, window=window,
        kv_len=Skv, bq=bq_, bk=bk_, interpret=interpret)
    out = out.reshape(B, H, Sq + pad_q, D)[:, :, :Sq]
    return out.astype(q.dtype)
