"""Flash attention Pallas kernel (online softmax) — the LM-stack prefill
hotspot.

Supports causal and sliding-window (local) masks — the gemma3 5:1
local:global and recurrentgemma local-attention layers need the window mask.
Block-level mask culling mirrors the spike_prop kernel's activity gating:
fully-masked (q-block, kv-block) tiles are skipped via ``pl.when``, so a
local-window layer's cost is O(S·W) not O(S²) — the structured-sparsity
cousin of the paper's event gating.

Grid: (batch*heads, q_blocks, kv_blocks), kv innermost; running max/sum and
the output accumulator live in VMEM scratch across kv iterations.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BQ = 128
DEFAULT_BK = 128
NEG_INF = -1e30


def _attn_body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale, causal, window, bq, bk, n_kv, kv_len):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = iq * bq
    k_start = ik * bk

    # block-level culling: skip tiles that are fully masked
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 >= q_start - window)

    @pl.when(live)
    def _tile():
        q = q_ref[0]                       # [bq, d]
        k = k_ref[0]                       # [bk, d]
        v = v_ref[0]                       # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        q_ids = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_ids = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_ids < kv_len              # kv padding
        if causal:
            mask = jnp.logical_and(mask, k_ids <= q_ids)
        if window is not None:
            mask = jnp.logical_and(mask, k_ids > q_ids - window - 1)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                # [bq, 1]
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _fin():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)
                    ).astype(o_ref.dtype)


def flash_attention_pallas(q, k, v, *, scale, causal=True, window=None,
                           kv_len=None, bq=DEFAULT_BQ, bk=DEFAULT_BK,
                           interpret=True):
    """q: [BH, Sq, D], k/v: [BH, Skv, D] (already GQA-expanded, padded to
    block multiples).  kv_len: true kv length before padding."""
    BH, Sq, D = q.shape
    Skv = k.shape[1]
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    n_q, n_kv = pl.cdiv(Sq, bq), pl.cdiv(Skv, bk)
    kv_len = Skv if kv_len is None else kv_len

    body = functools.partial(
        _attn_body, scale=scale, causal=causal, window=window, bq=bq, bk=bk,
        n_kv=n_kv, kv_len=kv_len)
    return pl.pallas_call(
        body,
        grid=(BH, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
