"""Pure-jnp oracle: materialized-scores softmax attention with the same
causal / sliding-window / GQA semantics."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal=True, window=None, scale=None):
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    groups = H // Hkv
    if groups > 1:
        k = jnp.repeat(k, groups, axis=1)
        v = jnp.repeat(v, groups, axis=1)
    if scale is None:
        scale = D ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_ids = jnp.arange(Sq)[:, None] + (Skv - Sq)  # align ends (decode case)
    k_ids = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_ids <= q_ids
    if window is not None:
        mask &= k_ids > q_ids - window - 1
    s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, p, 0.0)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
