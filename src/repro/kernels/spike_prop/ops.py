"""Blocked-ELL format builder + jit'd wrapper for the spike_prop kernel."""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.connectome import Connectome
from .kernel import SRC_BLK, TGT_BLK, spike_deliver_pallas


@dataclasses.dataclass(frozen=True)
class BlockedSynapses:
    """Dense (TGT_BLK x SRC_BLK) weight tiles for nonempty block pairs.

    blk_id[tb, e]  = source-block id of target-block tb's e-th tile
                     (pad tiles point at the zero spike block n_sb).
    weights[tb, e] = [TGT_BLK, SRC_BLK] dense tile (0 where no synapse).
    """

    blk_id: np.ndarray    # [n_tb, E] int32
    weights: np.ndarray   # [n_tb, E, TGT_BLK, SRC_BLK] f32
    n: int                # original neuron count
    n_tb: int
    n_sb: int
    occupancy: float      # nnz / stored-tile capacity (tile-format density)

    @property
    def tiles_stored(self) -> int:
        return int((self.blk_id < self.n_sb).sum())


def tile_coo(tgt: np.ndarray, src: np.ndarray, w: np.ndarray,
             n_tb: int, n_sb: int) -> tuple[np.ndarray, np.ndarray]:
    """Group a (target, source, weight) COO into blocked-ELL dense tiles.

    ``tgt`` indexes rows of an ``n_tb * TGT_BLK`` target space, ``src``
    columns of an ``n_sb * SRC_BLK`` source space (local vs global spaces
    are the caller's choice — the sharded builder passes local targets with
    *global* sources, which is the per-partition blk_id remap).  Returns
    ``(blk_id [n_tb, E], weights [n_tb, E, TGT_BLK, SRC_BLK])`` with E =
    the widest target block's tile count and pad tiles pointing at the
    zero spike block ``n_sb``.
    """
    tgt, src = tgt.astype(np.int64), src.astype(np.int64)
    tb, sb = tgt // TGT_BLK, src // SRC_BLK

    pair = tb * n_sb + sb
    order = np.argsort(pair, kind="stable")
    pair_s = pair[order]
    uniq_pairs, first = np.unique(pair_s, return_index=True)
    tiles_per_tb = np.bincount((uniq_pairs // n_sb).astype(np.int64),
                               minlength=n_tb)
    E = int(tiles_per_tb.max()) if len(tiles_per_tb) else 1

    blk_id = np.full((n_tb, E), n_sb, dtype=np.int32)
    weights = np.zeros((n_tb, E, TGT_BLK, SRC_BLK), dtype=np.float32)
    # slot index of each unique pair within its target block
    slot = np.arange(len(uniq_pairs)) - np.repeat(
        np.concatenate([[0], np.cumsum(tiles_per_tb)[:-1]]), tiles_per_tb)
    blk_id[(uniq_pairs // n_sb).astype(int), slot.astype(int)] = (
        uniq_pairs % n_sb)
    e_of_pair = np.empty(len(pair), dtype=np.int64)
    e_of_pair[order] = np.repeat(slot, np.diff(
        np.concatenate([first, [len(pair_s)]])))
    weights[tb, e_of_pair, tgt % TGT_BLK, src % SRC_BLK] += w
    return blk_id, weights


def build_blocked(c: Connectome, quantized: np.ndarray | None = None
                  ) -> BlockedSynapses:
    """Group the target-major CSR into dense tiles by (tgt//TB, src//SB)."""
    with obs.span("build", what="tile_store"):
        n = c.n
        n_tb = (n + TGT_BLK - 1) // TGT_BLK
        n_sb = (n + SRC_BLK - 1) // SRC_BLK
        w = (quantized if quantized is not None
             else c.in_weights).astype(np.float32)
        tgt = np.repeat(np.arange(n, dtype=np.int64), c.fan_in)
        blk_id, weights = tile_coo(tgt, c.in_indices, w, n_tb, n_sb)
        occ = c.nnz / max(1, (blk_id < n_sb).sum() * TGT_BLK * SRC_BLK)
    return BlockedSynapses(blk_id=blk_id, weights=weights, n=n, n_tb=n_tb,
                           n_sb=n_sb, occupancy=float(occ))


@dataclasses.dataclass(frozen=True)
class ShardedBlockedSynapses:
    """Per-partition tile stores over a DCSR mesh partitioning.

    Targets are partition-local (rows of partition p's ``U``-slot slab);
    sources stay *global*: ``blk_id[p]`` indexes the shared
    ``n_sb``-block global spike-bitmap space — the per-partition remap
    that lets each partition gate its own tiles against the one
    event-reconstructed global spike vector.
    """

    blk_id: np.ndarray    # [P, n_tb, E] int32 global source-block per tile
    weights: np.ndarray   # [P, n_tb, E, TGT_BLK, SRC_BLK] f32
    n_tb: int             # local target blocks per partition (ceil U/TGT_BLK)
    n_sb: int             # GLOBAL source blocks (ceil P*U/SRC_BLK)
    occupancy: float      # nnz / stored-tile capacity over all partitions

    @property
    def tiles_stored(self) -> int:
        return int((self.blk_id < self.n_sb).sum())


def build_blocked_sharded(d) -> ShardedBlockedSynapses:
    """Build stacked per-partition blocked-ELL stores from a DCSR snapshot
    (weights as partitioned/quantized by ``build_dcsr``).  All partitions
    share one tile width E = max over partitions so the stores stack into
    uniform shard_map/vmap operands."""
    with obs.span("build", what="tile_store_sharded"):
        return _build_blocked_sharded(d)


def _build_blocked_sharded(d) -> ShardedBlockedSynapses:
    P_, U = d.n_parts, d.part_size
    n_glob = P_ * U
    n_tb = (U + TGT_BLK - 1) // TGT_BLK
    n_sb = (n_glob + SRC_BLK - 1) // SRC_BLK

    valid = d.syn_src < n_glob
    stores = [tile_coo(d.syn_tgt_local[p][valid[p]],
                       d.syn_src[p][valid[p]],
                       d.syn_w[p][valid[p]].astype(np.float32),
                       n_tb, n_sb) for p in range(P_)]
    # uniform E: pad every partition's store to the widest target block
    E = max(b.shape[1] for b, _ in stores)
    blk_id = np.full((P_, n_tb, E), n_sb, dtype=np.int32)
    weights = np.zeros((P_, n_tb, E, TGT_BLK, SRC_BLK), dtype=np.float32)
    for p, (b, w) in enumerate(stores):
        blk_id[p, :, :b.shape[1]] = b
        weights[p, :, :b.shape[1]] = w
    nnz = int(valid.sum())
    occ = nnz / max(1, (blk_id < n_sb).sum() * TGT_BLK * SRC_BLK)
    return ShardedBlockedSynapses(blk_id=blk_id, weights=weights, n_tb=n_tb,
                                  n_sb=n_sb, occupancy=float(occ))


def spike_blocks(spikes, n: int, n_sb: int):
    """[n] bool/float spikes -> [n_sb+1, SRC_BLK] f32 blocks with a trailing
    zero pad block — no per-block counts (the fused kernel derives its
    block-live mask in VMEM)."""
    spk = jnp.asarray(spikes, jnp.float32)
    blocks = jnp.pad(spk, (0, n_sb * SRC_BLK - n)).reshape(n_sb, SRC_BLK)
    return jnp.concatenate([blocks, jnp.zeros((1, SRC_BLK), jnp.float32)])


def pad_spike_blocks(spikes, n: int, n_sb: int):
    """[n] bool/float spikes -> ([n_sb+1, SRC_BLK] f32 blocks with a trailing
    zero pad block, [n_sb+1] i32 per-block spike counts).  Traced per step;
    this is the only per-step host->kernel data movement."""
    spk_pad = spike_blocks(spikes, n, n_sb)
    nspk = spk_pad.sum(axis=1).astype(jnp.int32)
    return spk_pad, nspk


def fused_step(blk_id, weights, spk_pad, lif, drive, n: int, params,
               fixed_point: bool, interpret: bool):
    """Run the fused delivery->LIF kernel on an [n]-neuron LIF state.

    Shared by the monolithic ``blocked_fused`` engine and the sharded
    ``blocked`` exchange scheme's fused path: pads the LIF state and the
    stimulus drive channels to [n_tb, TGT_BLK] row blocks (the kernel's
    target geometry, matching the unfused ``out.reshape(-1)[:n]`` layout),
    invokes :func:`fused_deliver_lif_pallas`, and unpads.  ``drive`` is a
    :class:`repro.exp.stimulus.StimDrive`; ``None`` channels stay ``None``
    (absent from the kernel's operand list — no zero arrays streamed), and
    the fixed-point ``v_mv`` -> w_scale-units conversion happens here,
    exactly where ``repro.exp.stimulus.apply_drive`` does it on the
    unfused path.

    Returns ``(LIFState, spikes [n] bool)``.
    """
    from repro.core.neuron import LIFState
    from .kernel import fused_deliver_lif_pallas
    n_tb = blk_id.shape[0]
    rows = n_tb * TGT_BLK
    sdt = jnp.int32 if fixed_point else jnp.float32

    def rowblk(x, dtype):
        x = jnp.asarray(x).astype(dtype)
        return jnp.pad(x, (0, rows - n)).reshape(n_tb, TGT_BLK)

    gstim = None if drive.g_units is None else rowblk(drive.g_units,
                                                      jnp.float32)
    vin = None
    if drive.v_mv is not None:
        vin = rowblk(jnp.round(drive.v_mv / params.w_scale), jnp.int32) \
            if fixed_point else rowblk(drive.v_mv, jnp.float32)
    force = None if drive.force is None else rowblk(drive.force, jnp.int32)

    v, g, refrac, spk = fused_deliver_lif_pallas(
        blk_id, weights, spk_pad, rowblk(lif.v, sdt), rowblk(lif.g, sdt),
        rowblk(lif.refrac, jnp.int32), gstim, vin, force, params=params,
        fixed_point=fixed_point, interpret=interpret)
    unblk = lambda x: x.reshape(-1)[:n]
    return (LIFState(v=unblk(v), g=unblk(g), refrac=unblk(refrac)),
            unblk(spk).astype(bool))


@functools.partial(jax.jit, static_argnames=("n", "n_sb", "interpret"))
def _deliver(blk_id, weights, spikes, n, n_sb, interpret=True):
    spk_pad, nspk = pad_spike_blocks(spikes, n, n_sb)
    return spike_deliver_pallas(blk_id, weights, spk_pad, nspk,
                                interpret=interpret)


def spike_deliver(bs: BlockedSynapses, spikes, *, interpret: bool = True,
                  device_arrays=None):
    """spikes: [n] bool/float.  Returns g drive [n] f32.

    ``device_arrays``: optional (blk_id, weights) jnp arrays to avoid
    re-uploading the tile store every call.  (The ``blocked`` simulation
    engine in :mod:`repro.core.engines.blocked` keeps the tiles
    device-resident for the whole run; this wrapper is the standalone /
    test entry point.)
    """
    blk_id, weights = (device_arrays if device_arrays is not None
                       else (jnp.asarray(bs.blk_id), jnp.asarray(bs.weights)))
    out = _deliver(blk_id, weights, jnp.asarray(spikes), bs.n, bs.n_sb,
                   interpret=interpret)
    return out.reshape(-1)[:bs.n]
