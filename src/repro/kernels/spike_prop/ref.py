"""Pure-jnp oracles for the block-gated spike delivery kernel."""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.connectome import Connectome


def spike_deliver_dense_ref(c: Connectome, spikes,
                            quantized: np.ndarray | None = None):
    """Oracle 1: dense W @ s against the original connectome."""
    w = (quantized if quantized is not None else c.in_weights)
    dense = np.zeros((c.n, c.n), np.float32)
    tgt = np.repeat(np.arange(c.n), c.fan_in)
    dense[tgt, c.in_indices] = w.astype(np.float32)
    return jnp.asarray(dense) @ jnp.asarray(spikes, jnp.float32)


def spike_deliver_ref(bs, spikes):
    """Oracle 2: tile math in plain jnp over the *blocked* store —
    isolates kernel-mechanics bugs from format-builder bugs."""
    from .kernel import SRC_BLK
    n, n_sb = bs.n, bs.n_sb
    spk = jnp.asarray(spikes, jnp.float32)
    spk = jnp.pad(spk, (0, n_sb * SRC_BLK - n))
    blocks = jnp.concatenate([spk.reshape(n_sb, SRC_BLK),
                              jnp.zeros((1, SRC_BLK), jnp.float32)])
    sv = blocks[jnp.asarray(bs.blk_id)]             # [n_tb, E, SRC_BLK]
    out = jnp.einsum("tebs,tes->tb", jnp.asarray(bs.weights), sv)
    return out.reshape(-1)[:n]
