from .ops import (BlockedSynapses, build_blocked, fused_step, spike_blocks,
                  spike_deliver)
from .ref import spike_deliver_ref, spike_deliver_dense_ref

__all__ = ["BlockedSynapses", "build_blocked", "fused_step", "spike_blocks",
           "spike_deliver", "spike_deliver_ref", "spike_deliver_dense_ref"]
