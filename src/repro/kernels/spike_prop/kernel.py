"""Block-gated synaptic-delivery Pallas kernel (TPU adaptation of the
paper's event-driven spike propagation).

Loihi 2 delivers each spike event through per-core synaptic memory; cost is
proportional to spike activity.  A TPU has no per-event branching — the
native granularity of an "event" is a tile.  We therefore adapt the paper's
insight as *block-level* event-driven delivery:

  * synapses are grouped into dense (TGT_BLK x SRC_BLK) weight tiles, stored
    only for (target-block, source-block) pairs that contain synapses
    (blocked-ELL: each target block owns up to E tiles);
  * per step the kernel walks grid (target_blocks, E) and for each tile
    checks the *source-block spike count* — if the source block emitted no
    spikes this step, the whole tile's matvec is skipped via ``pl.when``
    (the MXU work and the HBM->VMEM weight-tile stream for gated tiles is
    saved on real hardware via the grid-level DMA skip);
  * live tiles do a dense [TGT_BLK, SRC_BLK] x [SRC_BLK] matvec on the MXU
    and accumulate into the target block's conductance drive.

Cost ∝ (number of live tiles) — the TPU-native rendering of "execution cost
proportional to spiking activity rather than synapse count".

BlockSpec geometry: weight tiles [1, TGT_BLK, SRC_BLK] stream through VMEM
indexed by (tb, e); the spike vector is blocked [SRC_BLK] by the tile's
source-block id via a scalar-prefetch index map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TGT_BLK = 128
SRC_BLK = 128


def _deliver_body(blk_id_ref, spk_ref, w_ref, nspk_ref, out_ref):
    """grid = (n_tgt_blocks, E); accumulate gated tile matvecs."""
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    live = nspk_ref[0] > 0

    @pl.when(live)
    def _tile():
        w = w_ref[0, 0]                   # [TGT_BLK, SRC_BLK] f32
        s = spk_ref[...]                  # [1, SRC_BLK] f32 spike block
        # MXU matvec as [TGT, SRC] @ [SRC, 1] -> transpose to the (1, TGT) row
        out_ref[...] += jax.lax.dot_general(
            w, s, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).T


def spike_deliver_pallas(blk_id, weights, spk_blocks, nspk_blocks,
                         interpret: bool = True):
    """Args:
      blk_id:      [n_tb, E] int32 source-block id per tile (pad rows allowed
                   — they point at an all-zero spike block).
      weights:     [n_tb, E, TGT_BLK, SRC_BLK] f32 dense tiles.
      spk_blocks:  [n_sb + 1, SRC_BLK] f32 spikes grouped by source block;
                   row n_sb is the zero pad block.
      nspk_blocks: [n_sb + 1] int32 per-source-block spike counts.
    Returns: [n_tb, TGT_BLK] f32 accumulated drive.
    """
    n_tb, E = blk_id.shape
    grid = (n_tb, E)
    kwargs = {}
    # class name varies across jax releases (TPUCompilerParams -> CompilerParams)
    params_cls = getattr(pltpu, "TPUCompilerParams", None) or \
        getattr(pltpu, "CompilerParams", None)
    if not interpret and params_cls is not None:
        # target blocks are independent; the E axis accumulates into the
        # same output block and must stay sequential.
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "arbitrary"))
    # scalar-prefetch: the blk_id table is prefetched to SMEM and drives the
    # spike-block / spike-count index maps (data-dependent DMA scheduling).
    kernel = pl.pallas_call(
        _deliver_body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, SRC_BLK), lambda tb, e, blk: (blk[tb, e], 0)),
                pl.BlockSpec((1, 1, TGT_BLK, SRC_BLK),
                             lambda tb, e, blk: (tb, e, 0, 0)),
                pl.BlockSpec((1,), lambda tb, e, blk: (blk[tb, e],)),
            ],
            out_specs=pl.BlockSpec((1, TGT_BLK), lambda tb, e, blk: (tb, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_tb, TGT_BLK), jnp.float32),
        interpret=interpret,
        **kwargs,
    )
    return kernel(blk_id, spk_blocks, weights, nspk_blocks)
