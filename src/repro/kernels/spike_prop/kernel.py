"""Block-gated synaptic-delivery Pallas kernel (TPU adaptation of the
paper's event-driven spike propagation).

Loihi 2 delivers each spike event through per-core synaptic memory; cost is
proportional to spike activity.  A TPU has no per-event branching — the
native granularity of an "event" is a tile.  We therefore adapt the paper's
insight as *block-level* event-driven delivery:

  * synapses are grouped into dense (TGT_BLK x SRC_BLK) weight tiles, stored
    only for (target-block, source-block) pairs that contain synapses
    (blocked-ELL: each target block owns up to E tiles);
  * per step the kernel walks grid (target_blocks, E) and for each tile
    checks the *source-block spike count* — if the source block emitted no
    spikes this step, the whole tile's matvec is skipped via ``pl.when``
    (the MXU work and the HBM->VMEM weight-tile stream for gated tiles is
    saved on real hardware via the grid-level DMA skip);
  * live tiles do a dense [TGT_BLK, SRC_BLK] x [SRC_BLK] matvec on the MXU
    and accumulate into the target block's conductance drive.

Cost ∝ (number of live tiles) — the TPU-native rendering of "execution cost
proportional to spiking activity rather than synapse count".

BlockSpec geometry: weight tiles [1, TGT_BLK, SRC_BLK] stream through VMEM
indexed by (tb, e); the spike vector is blocked [SRC_BLK] by the tile's
source-block id via a scalar-prefetch index map.

The fused variant (:func:`fused_deliver_lif_pallas`) goes one step
further and closes the paper's whole per-timestep loop inside VMEM:
after the last live tile of a target-row block has been accumulated, the
same kernel invocation applies the :mod:`repro.kernels.lif` neuron body
(int32 Q19.12 Loihi-faithful path or float32) to that block and emits
the spike vector directly.  The delivered current lives only in a VMEM
scratch accumulator — it never round-trips through HBM between delivery
and integration, which is exactly the locality the paper credits for
Loihi 2's speed (spike delivery and neuron update share one local
memory).  The tile-skip decision is fused too: the per-block any-spike
mask (``repro.core.compaction.two_level_active``'s first level) is
re-derived from the VMEM-resident spike block instead of arriving as a
precomputed count array, so neither the delivered currents nor the block
mask ever leave VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.neuron import LIFState, lif_step, lif_step_fx

TGT_BLK = 128
SRC_BLK = 128


def _deliver_body(blk_id_ref, spk_ref, w_ref, nspk_ref, out_ref):
    """grid = (n_tgt_blocks, E); accumulate gated tile matvecs."""
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    live = nspk_ref[0] > 0

    @pl.when(live)
    def _tile():
        w = w_ref[0, 0]                   # [TGT_BLK, SRC_BLK] f32
        s = spk_ref[...]                  # [1, SRC_BLK] f32 spike block
        # MXU matvec as [TGT, SRC] @ [SRC, 1] -> transpose to the (1, TGT) row
        out_ref[...] += jax.lax.dot_general(
            w, s, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).T


def spike_deliver_pallas(blk_id, weights, spk_blocks, nspk_blocks,
                         interpret: bool = True):
    """Args:
      blk_id:      [n_tb, E] int32 source-block id per tile (pad rows allowed
                   — they point at an all-zero spike block).
      weights:     [n_tb, E, TGT_BLK, SRC_BLK] f32 dense tiles.
      spk_blocks:  [n_sb + 1, SRC_BLK] f32 spikes grouped by source block;
                   row n_sb is the zero pad block.
      nspk_blocks: [n_sb + 1] int32 per-source-block spike counts.
    Returns: [n_tb, TGT_BLK] f32 accumulated drive.
    """
    n_tb, E = blk_id.shape
    grid = (n_tb, E)
    kwargs = {}
    # class name varies across jax releases (TPUCompilerParams -> CompilerParams)
    params_cls = getattr(pltpu, "TPUCompilerParams", None) or \
        getattr(pltpu, "CompilerParams", None)
    if not interpret and params_cls is not None:
        # target blocks are independent; the E axis accumulates into the
        # same output block and must stay sequential.
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "arbitrary"))
    # scalar-prefetch: the blk_id table is prefetched to SMEM and drives the
    # spike-block / spike-count index maps (data-dependent DMA scheduling).
    kernel = pl.pallas_call(
        _deliver_body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, SRC_BLK), lambda tb, e, blk: (blk[tb, e], 0)),
                pl.BlockSpec((1, 1, TGT_BLK, SRC_BLK),
                             lambda tb, e, blk: (tb, e, 0, 0)),
                pl.BlockSpec((1,), lambda tb, e, blk: (blk[tb, e],)),
            ],
            out_specs=pl.BlockSpec((1, TGT_BLK), lambda tb, e, blk: (tb, 0)),
        ),
        out_shape=jax.ShapeDtypeStruct((n_tb, TGT_BLK), jnp.float32),
        interpret=interpret,
        **kwargs,
    )
    return kernel(blk_id, spk_blocks, weights, nspk_blocks)


# --------------------------------------------------------------------------
# Fused delivery -> LIF: the whole timestep of a target-row block in VMEM
# --------------------------------------------------------------------------

def _accumulate_tile(spk_ref, w_ref, acc_ref):
    """Shared delivery preamble of the fused bodies: zero the VMEM
    accumulator on the first tile slot, then add the gated tile matvec.

    The live check re-derives the per-block any-spike mask (the first
    level of ``repro.core.compaction.two_level_active``) from the
    VMEM-resident spike block — equivalent to the unfused kernel's
    ``nspk > 0`` gate (spike lanes are exactly 0/1) but the mask is never
    materialized outside the kernel.
    """
    e = pl.program_id(1)

    @pl.when(e == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s = spk_ref[...]                      # [1, SRC_BLK] f32 spike block
    live = jnp.any(s != 0.0)

    @pl.when(live)
    def _tile():
        w = w_ref[0, 0]                   # [TGT_BLK, SRC_BLK] f32
        acc_ref[...] += jax.lax.dot_general(
            w, s, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32).T


def _fused_body(blk_id_ref, spk_ref, w_ref, v_ref, g_ref, ref_ref, *rest,
                params, fixed_point, use_gstim, use_vin, use_force):
    """grid = (n_tgt_blocks, E): accumulate, then integrate on the last
    slot.  The integration is not a re-implementation: it CALLS the very
    ``lif_step`` / ``lif_step_fx`` the unfused step body runs (pure jnp on
    the VMEM-resident block values), so bit-identity to the unfused
    composition is structural, not hand-synchronized.  Stimulus channels
    the caller's drive lacks are absent from the operand list entirely
    (``use_*`` flags), exactly mirroring ``apply_drive``'s ``None``
    short-circuits — and sparing their HBM->VMEM streams."""
    it = iter(rest[:use_gstim + use_vin + use_force])
    gstim_ref = next(it) if use_gstim else None
    vin_ref = next(it) if use_vin else None
    force_ref = next(it) if use_force else None
    v_out, g_out, refr_out, spk_out, acc_ref = \
        rest[use_gstim + use_vin + use_force:]

    _accumulate_tile(spk_ref, w_ref, acc_ref)
    e = pl.program_id(1)

    @pl.when(e == pl.num_programs(1) - 1)
    def _integrate():
        g_units = acc_ref[...]
        if use_gstim:
            g_units = g_units + gstim_ref[...]
        lif = LIFState(v=v_ref[...], g=g_ref[...], refrac=ref_ref[...])
        vin = vin_ref[...] if use_vin else None
        force = (force_ref[...] != 0) if use_force else None
        if fixed_point:
            # f32 accumulation -> integer weight units at the block
            # boundary, exactly apply_drive's conversion point
            st, spikes = lif_step_fx(
                lif, jnp.round(g_units).astype(jnp.int32), params, vin,
                force)
        else:
            st, spikes = lif_step(lif, g_units * params.w_scale, params,
                                  vin, force)
        v_out[...] = st.v
        g_out[...] = st.g
        refr_out[...] = st.refrac
        spk_out[...] = spikes.astype(jnp.int32)


def fused_deliver_lif_pallas(blk_id, weights, spk_blocks, v, g, refrac,
                             gstim=None, vin=None, force=None, *, params,
                             fixed_point: bool, interpret: bool = True):
    """One call = one whole timestep: spike->gather->accumulate->integrate->
    threshold per 128-neuron target-row block, entirely in VMEM.

    Args:
      blk_id / weights / spk_blocks: as :func:`spike_deliver_pallas` (no
        spike-count array — the block-live mask is derived in-kernel).
      v, g, refrac: LIF state as [n_tb, TGT_BLK] row blocks (f32 or
        Q19.12 int32 per ``fixed_point``; refrac always int32).
      gstim: optional [n_tb, TGT_BLK] f32 stimulus drive in weight units.
      vin:   optional [n_tb, TGT_BLK] membrane drive — mV f32 (float
        path) or pre-rounded w_scale units int32 (fixed-point path).
      force: optional [n_tb, TGT_BLK] int32 forced-spike mask.
      ``None`` channels are dropped from the operand list entirely (no
      zero arrays streamed), mirroring the unfused path's ``None``
      short-circuits.
    Returns: (v, g, refrac, spikes) row blocks; spikes int32 0/1.
    """
    n_tb, E = blk_id.shape
    grid = (n_tb, E)
    sdt = jnp.int32 if fixed_point else jnp.float32
    body = functools.partial(
        _fused_body, params=params, fixed_point=fixed_point,
        use_gstim=gstim is not None, use_vin=vin is not None,
        use_force=force is not None)
    kwargs = {}
    params_cls = getattr(pltpu, "TPUCompilerParams", None) or \
        getattr(pltpu, "CompilerParams", None)
    if not interpret and params_cls is not None:
        kwargs["compiler_params"] = params_cls(
            dimension_semantics=("parallel", "arbitrary"))
    row = pl.BlockSpec((1, TGT_BLK), lambda tb, e, blk: (tb, 0))
    stim_ops = [x for x in (gstim, vin, force) if x is not None]
    kernel = pl.pallas_call(
        body,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, SRC_BLK), lambda tb, e, blk: (blk[tb, e], 0)),
                pl.BlockSpec((1, 1, TGT_BLK, SRC_BLK),
                             lambda tb, e, blk: (tb, e, 0, 0)),
            ] + [row] * (3 + len(stim_ops)),
            out_specs=[row, row, row, row],
            # the delivered current's only home: a VMEM scratch accumulator
            scratch_shapes=[pltpu.VMEM((1, TGT_BLK), jnp.float32)],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((n_tb, TGT_BLK), sdt),
            jax.ShapeDtypeStruct((n_tb, TGT_BLK), sdt),
            jax.ShapeDtypeStruct((n_tb, TGT_BLK), jnp.int32),
            jax.ShapeDtypeStruct((n_tb, TGT_BLK), jnp.int32),
        ],
        interpret=interpret,
        **kwargs,
    )
    return kernel(blk_id, spk_blocks, weights, v, g, refrac, *stim_ops)
