"""Assigned input-shape cells and per-(arch x cell) input specs.

Four cells per LM architecture:
  train_4k     seq 4,096   global_batch 256   (training step)
  prefill_32k  seq 32,768  global_batch 32    (inference prefill)
  decode_32k   one token, 32,768-entry KV     global_batch 128
  long_500k    one token, 524,288-entry KV    global_batch 1
               (sub-quadratic archs only)

Family adjustments (documented in DESIGN.md):
  * whisper-medium: encoder fixed at 1500 frames, decoder at its
    architectural max 448; decode cells use that max; long_500k skipped.
  * llava-next: n_patches stub embeddings occupy the head of the sequence.
  * pure full-attention archs skip long_500k.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import ModelConfig, init_cache

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def cell_supported(cfg: ModelConfig, cell: str) -> tuple[bool, str]:
    if cell == "long_500k":
        if cfg.is_encdec:
            return False, "whisper decoder max context is 448"
        if not cfg.subquadratic:
            return False, "pure full-attention arch; 500k decode skipped"
    return True, ""


def _tok(b, s):
    return jax.ShapeDtypeStruct((b, s), jnp.int32)


def input_specs(cfg: ModelConfig, cell: str, smoke_scale: bool = False):
    """ShapeDtypeStruct stand-ins for one step of the given cell.

    Returns dict with keys depending on kind:
      train:   batch={tokens, labels, (patches|frames)}
      prefill: batch={tokens, (patches|frames)}
      decode:  tokens [B], pos scalar   (cache specs built separately via
               cache_specs()).
    """
    spec = SHAPES[cell]
    B, S = spec["batch"], spec["seq"]
    if smoke_scale:
        B, S = max(2, B // 128), max(32, S // 512)
    kind = spec["kind"]

    if cfg.is_encdec:
        # whisper: clamp to (enc 1500 frames, dec 448 tokens)
        Sd = cfg.dec_max
        frames = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model),
                                      jnp.float32)
        if kind == "train":
            return {"batch": {"frames": frames, "tokens": _tok(B, Sd),
                              "labels": _tok(B, Sd)}}
        if kind == "prefill":
            return {"batch": {"frames": frames, "tokens": _tok(B, Sd)}}
        return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    if kind in ("train", "prefill"):
        batch = {}
        S_tok = S
        if cfg.n_patches:
            S_tok = S - cfg.n_patches
            batch["patches"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), jnp.float32)
        batch["tokens"] = _tok(B, S_tok)
        if kind == "train":
            batch["labels"] = _tok(B, S_tok)
        return {"batch": batch}
    return {"tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}


def cache_specs(cfg: ModelConfig, cell: str, dtype=jnp.float32,
                smoke_scale: bool = False):
    """Abstract decode-cache pytree for a decode cell."""
    spec = SHAPES[cell]
    B, S = spec["batch"], spec["seq"]
    if smoke_scale:
        B, S = max(2, B // 128), max(32, S // 512)
    return jax.eval_shape(lambda: init_cache(cfg, B, S, dtype))
