"""Architecture registry: one module per assigned architecture + flywire.

``get_config(name)`` returns the full published config; ``get_config(name,
smoke=True)`` returns the reduced same-family smoke variant (small widths,
few layers — same block pattern) used by CPU tests.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "grok1_314b",
    "llama4_scout_17b_a16e",
    "recurrentgemma_2b",
    "phi3_medium_14b",
    "qwen2_5_14b",
    "command_r_35b",
    "gemma3_12b",
    "whisper_medium",
    "rwkv6_7b",
    "llava_next_34b",
]

# canonical ids from the assignment -> module names
ALIASES = {
    "grok-1-314b": "grok1_314b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2.5-14b": "qwen2_5_14b",
    "command-r-35b": "command_r_35b",
    "gemma3-12b": "gemma3_12b",
    "whisper-medium": "whisper_medium",
    "rwkv6-7b": "rwkv6_7b",
    "llava-next-34b": "llava_next_34b",
}


def get_config(name: str, smoke: bool = False):
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_arch_names():
    return list(ALIASES.keys())
