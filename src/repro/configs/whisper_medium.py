"""whisper-medium [audio] — enc-dec, 24L each, d_model=1024 16H (kv=16)
d_ff=4096 vocab=51865.  Conv frontend is a STUB per the task spec:
input_specs() provides precomputed 1500-frame embeddings.  Decoder
architectural max context = 448 tokens; 32k/500k cells are clamped to
(enc 1500, dec 448) and documented.  [arXiv:2212.04356; unverified]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium", family="encdec", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=4096, vocab=51865,
    n_enc_layers=24, enc_seq=1500, dec_max=448,
    use_rope=False, learned_pos=448, gated_mlp=False,
    act="gelu", norm="ln",
)

SMOKE = ModelConfig(
    name="whisper-smoke", family="encdec", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
    n_enc_layers=2, enc_seq=32, dec_max=16,
    use_rope=False, learned_pos=16, gated_mlp=False,
    act="gelu", norm="ln",
)
