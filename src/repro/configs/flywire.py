"""flywire — the paper's own workload: the FlyWire connectome LIF network
(139,255 neurons / ~15M condensed synapses) with the sugar-neuron
experiment and the background-activity scaling study."""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.connectome import FLYWIRE_N_NEURONS
from repro.core.engine import SimConfig
from repro.core.neuron import FLYWIRE_LIF, FLYWIRE_LIF_1MS


@dataclasses.dataclass(frozen=True)
class FlyWireConfig:
    n_neurons: int = FLYWIRE_N_NEURONS
    target_synapses: int = 15_000_000
    n_sugar: int = 20
    sugar_rate_hz: float = 150.0
    t_sim_ms: float = 1000.0
    sim: SimConfig = SimConfig(params=FLYWIRE_LIF, engine="event",
                               quantize_bits=9, fixed_point=True,
                               poisson_to_v=False)

    @property
    def t_steps(self) -> int:
        return int(round(self.t_sim_ms / self.sim.params.dt))

    def sugar_neurons(self, seed: int = 0) -> np.ndarray:
        rng = np.random.default_rng(seed)
        return rng.choice(self.n_neurons, self.n_sugar, replace=False)


CONFIG = FlyWireConfig()
CONFIG_1MS = FlyWireConfig(
    sim=SimConfig(params=FLYWIRE_LIF_1MS, engine="event", quantize_bits=9,
                  fixed_point=True, poisson_to_v=False))
SMOKE = FlyWireConfig(n_neurons=2000, target_synapses=60_000, t_sim_ms=50.0)
