"""qwen2.5-14b [dense] — 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064.  GQA with QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b", family="dense", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=13824, vocab=152064,
    qkv_bias=True, act="silu", norm="rms",
)

SMOKE = ModelConfig(
    name="qwen2.5-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    qkv_bias=True, act="silu", norm="rms",
)
