"""rwkv6-7b [ssm] — Finch: 32L d_model=4096 (attn-free) d_ff=14336
vocab=65536.  Data-dependent decay; O(1)/token decode -> long_500k runs.
[arXiv:2404.05892; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096,
    n_heads=64, n_kv_heads=64, d_head=64, d_ff=14336, vocab=65536,
    block_pattern=("rwkv",), use_rope=False, norm="ln",
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="ssm", n_layers=2, d_model=128,
    n_heads=2, n_kv_heads=2, d_head=64, d_ff=256, vocab=512,
    block_pattern=("rwkv",), use_rope=False, norm="ln",
    subquadratic=True,
)
