"""gemma3-12b [dense] — 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144.  5:1 local:global attention, 128k context, d_head=256.
long_500k runs: decode cost is dominated by the 1024-window local layers;
the 1-in-6 global layers decode at O(S) (linear) with seq-sharded KV.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b", family="dense", n_layers=48, d_model=3840,
    n_heads=16, n_kv_heads=8, d_head=256, d_ff=15360, vocab=262144,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=1024, tie_embeddings=True, act="gelu", norm="rms",
    subquadratic=True,
)

SMOKE = ModelConfig(
    name="gemma3-smoke", family="dense", n_layers=6, d_model=96,
    n_heads=4, n_kv_heads=2, d_head=24, d_ff=192, vocab=512,
    block_pattern=("local", "local", "local", "local", "local", "attn"),
    window=16, tie_embeddings=True, act="gelu", norm="rms",
    subquadratic=True,
)
