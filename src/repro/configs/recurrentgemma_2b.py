"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (GQA kv=1) d_ff=7680
vocab=256000.  RG-LRU + local attention, 2 recurrent : 1 local-attn pattern.
[arXiv:2402.19427; hf]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_head=256, d_ff=7680, vocab=256000,
    block_pattern=("rglru", "rglru", "local"), window=2048, d_rnn=2560,
    tie_embeddings=True, act="gelu", norm="rms", subquadratic=True,
)

SMOKE = ModelConfig(
    name="recurrentgemma-smoke", family="hybrid", n_layers=5, d_model=96,
    n_heads=4, n_kv_heads=1, d_head=24, d_ff=192, vocab=512,
    block_pattern=("rglru", "rglru", "local"), window=16, d_rnn=96,
    tie_embeddings=True, act="gelu", norm="rms", subquadratic=True,
)
