"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 16 experts top-1 + shared expert, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab=202048,
    n_experts=16, top_k=1, shared_expert=True, act="silu", norm="rms",
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    n_experts=4, top_k=1, shared_expert=True, act="silu", norm="rms",
    # dropless at smoke scale: capacity drops are a modelled approximation
    # and would mask prefill/decode cache bugs in the consistency tests
    moe_capacity_factor=0.0,
)
