"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000.  Anyres patch tiling is a STUB per the task spec:
input_specs() provides 576 precomputed patch embeddings prepended to the
token sequence.  [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm", n_layers=60, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=20480, vocab=64000,
    n_patches=576, act="silu", norm="rms",
)

SMOKE = ModelConfig(
    name="llava-next-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    n_patches=4, act="silu", norm="rms",
)
