"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) d_ff=32768
vocab=131072, MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=32768, vocab=131072,
    n_experts=8, top_k=2, act="gelu", norm="rms", use_rope=True,
)

SMOKE = ModelConfig(
    name="grok-1-314b-smoke", family="moe", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    n_experts=4, top_k=2, act="gelu", norm="rms", use_rope=True,
    # dropless at smoke scale: capacity drops are a modelled approximation
    # and would mask prefill/decode cache bugs in the consistency tests
    moe_capacity_factor=0.0,
)
