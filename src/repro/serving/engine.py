"""Batched serving engine: slot-based continuous batching over the
prefill/decode API.

A fixed pool of B decode slots shares one KV cache [.., B, .., max_len, ..].
Incoming requests are prefilled one at a time (prefill writes the request's
kv into its slot via a scatter) and then decoded jointly — each decode_step
advances every live slot by one token.  Finished slots (EOS or length
limit) are recycled.  This is the standard vLLM-style loop reduced to its
JAX-native core: all slot state is device-resident; the host only moves
request text in and tokens out.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 32
    out: list = dataclasses.field(default_factory=list)
    done: bool = False
    truncated: bool = False       # run() hit max_steps with this in flight


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_slots: int = 4
    max_len: int = 256
    eos_id: int = -1              # -1: never stop early


class ServingEngine:
    def __init__(self, params, cfg, serve_cfg: ServeConfig):
        self.params = params
        self.cfg = cfg
        self.sc = serve_cfg
        B, L = serve_cfg.batch_slots, serve_cfg.max_len
        self.cache = init_cache(cfg, B, L)
        # int32 from the start: decode_step wants int32 positions, so an
        # int64 store would force a downcast copy on every step()
        self.pos = np.zeros(B, dtype=np.int32)          # per-slot write pos
        self.live: list[Optional[Request]] = [None] * B
        # always-on accounting: the registry is bound at construction, so
        # admission/decode counters and compile-cache hit rates accumulate
        # with or without an ambient telemetry session (ROADMAP's
        # "surface hit rates" for the serving loop)
        self.metrics = obs.MetricsRegistry()
        self._queue_depth = 0          # pending requests at last run() tick
        self._decode = obs.InstrumentedJit(
            jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg)),
            "serving.decode", registry=self.metrics)
        self._prefill1 = obs.InstrumentedJit(
            jax.jit(lambda p, b: prefill(p, b, cfg, L)),
            "serving.prefill", registry=self.metrics)

    def stats(self) -> dict:
        """Point-in-time snapshot: queue/slot occupancy plus the
        cumulative admission, decode, and compile-cache counters."""
        c = self.metrics.counters()
        live = sum(r is not None for r in self.live)
        cache = self.metrics.compile_snapshot()
        return {
            "slots_live": live,
            "slots_free": self.sc.batch_slots - live,
            "queue_depth": self._queue_depth,
            "admitted": int(c.get("serving.admitted", 0)),
            "rejected": int(c.get("serving.rejected", 0)),
            "decode_steps": int(c.get("serving.decode_steps", 0)),
            "tokens_generated": int(c.get("serving.tokens", 0)),
            "truncated": int(c.get("serving.truncated", 0)),
            "compile_cache": {"hits": cache["hits"],
                              "misses": cache["misses"]},
        }

    # -- slot management ---------------------------------------------------

    def _free_slot(self) -> Optional[int]:
        for i, r in enumerate(self.live):
            if r is None:
                return i
        return None

    def add_request(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            self.metrics.inc("serving.rejected")
            return False
        self.metrics.inc("serving.admitted")
        # prefill the single request, then scatter its cache into the slot
        batch = {"tokens": jnp.asarray(req.prompt)[None]}
        logits, rcache = self._prefill1(self.params, batch)
        tok = int(jnp.argmax(logits[0]))
        req.out.append(tok)

        # scatter along the batch axis of every cache leaf
        def scatter(leaf_slots, leaf_one):
            # batch axis: first axis whose size == batch_slots and == 1 in
            # the single-request cache at the same position
            ax = _batch_axis(leaf_slots.shape, leaf_one.shape,
                             self.sc.batch_slots)
            idx = [slice(None)] * leaf_slots.ndim
            idx[ax] = slice(slot, slot + 1)
            return leaf_slots.at[tuple(idx)].set(leaf_one)

        self.cache = jax.tree.map(scatter, self.cache, rcache)
        self.pos[slot] = len(req.prompt)
        self.live[slot] = req
        return True

    # -- decode ------------------------------------------------------------

    def step(self) -> list[Request]:
        """One joint decode step across all live slots; returns the
        requests whose slot finished (EOS / length limit) this step."""
        if not any(r is not None for r in self.live):
            return []
        B = self.sc.batch_slots
        toks = np.zeros(B, dtype=np.int32)
        for i, r in enumerate(self.live):
            if r is not None:
                toks[i] = r.out[-1]
        # per-slot positions: each live slot writes kv at its own pos
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(toks),
                                          jnp.asarray(self.pos))
        self.metrics.inc("serving.decode_steps")
        nxt = np.asarray(jnp.argmax(logits, -1))
        finished: list[Request] = []
        for i, r in enumerate(self.live):
            if r is None:
                continue
            self.metrics.inc("serving.tokens")
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            if (len(r.out) >= r.max_new or
                    int(nxt[i]) == self.sc.eos_id or
                    self.pos[i] >= self.sc.max_len - 1):
                r.done = True
                self.live[i] = None
                finished.append(r)
        return finished

    def run(self, requests: list[Request], max_steps: int = 10_000):
        """Serve a workload; returns ALL submitted requests in completion
        order.  Per-slot completion is tracked from :meth:`step`'s return
        (O(finished) per step, not an O(n²) rescan of the workload), and a
        request still in flight or still queued when ``max_steps`` runs
        out comes back with ``truncated=True`` instead of silently
        vanishing — callers can always account for every submission."""
        pending = list(requests)
        done: list[Request] = []
        steps = 0
        while (pending or any(r is not None for r in self.live)) \
                and steps < max_steps:
            while pending and self._free_slot() is not None:
                self.add_request(pending.pop(0))
            self._queue_depth = len(pending)
            done.extend(self.step())
            steps += 1
        leftover = [r for r in self.live if r is not None] + pending
        for r in leftover:
            r.truncated = True
            self.metrics.inc("serving.truncated")
        self.live = [None] * self.sc.batch_slots
        self._queue_depth = 0
        return done + leftover


def _batch_axis(slot_shape, one_shape, batch_slots) -> int:
    for ax, (a, b) in enumerate(zip(slot_shape, one_shape)):
        if a == batch_slots and b == 1:
            return ax
    # fall back: first axis that differs
    for ax, (a, b) in enumerate(zip(slot_shape, one_shape)):
        if a != b:
            return ax
    raise ValueError(f"no batch axis in {slot_shape} vs {one_shape}")
