"""Serving layer: production-shaped front ends over the batched compute
cores.

Two engines share the slot/batching vocabulary:

* :mod:`repro.serving.engine` — the LM serving loop (continuous batching
  over prefill/decode, slot-recycled KV cache).
* :mod:`repro.serving.sim` — simulation-as-a-service for the connectome
  simulator: admission control, batching by compile signature onto one
  vmapped chunked scan, per-lane health attribution, retry/backoff,
  poison quarantine, load shedding, graceful degradation.  See
  ``docs/serving.md``.
"""

from .engine import Request, ServeConfig, ServingEngine
from .sim import (COMPLETED, QUARANTINED, QUEUED, REJECTED, TERMINAL,
                  SimRequest, SimServeConfig, SimServer)

__all__ = [
    "Request", "ServeConfig", "ServingEngine",
    "COMPLETED", "QUARANTINED", "QUEUED", "REJECTED", "TERMINAL",
    "SimRequest", "SimServeConfig", "SimServer",
]
