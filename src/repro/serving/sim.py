"""Simulation-as-a-service: a fault-tolerant batched serving front end
for the connectome simulator.

The paper's headline is throughput on a *shared* neuromorphic platform —
12 Loihi 2 chips serving one 140K-neuron connectome to whoever asks —
and the natural workload shape is many independent experiments (stimulus
-> propagation -> readout) from many callers.  This module is the front
end that survives that workload instead of assuming a single cooperative
caller.  A request is ``(scenario, stimulus params, probes, duration,
seed, deadline, priority)``; the server:

* **admits** against a bounded queue (overflow is shed immediately with
  a reason — overload degrades into explicit rejections, never unbounded
  latency);
* **batches by compile signature**: requests that share
  ``(scenario, params, t_steps, probes)`` differ only in their PRNG seed,
  which is exactly the axis :func:`repro.exp.run_trials` vmaps over — a
  batch becomes ONE chunked, vmapped scan, so the compile cache
  (PR 7's ``InstrumentedJit``) hits on every tick after the first and a
  packed request's result is **bit-identical** to a solo
  :func:`repro.core.simulate` run (pinned in tests/test_serving_sim.py);
* **supervises at chunk boundaries** (PR 6's chunked driver): per-request
  wall-clock deadlines, and per-*lane* health sentinels
  (:func:`repro.core.health.lane_snapshots`), so a poisoned request is
  attributed to its lane instead of condemning the batch;
* **retries transient faults** with jittered exponential backoff
  (:class:`repro.core.health.BackoffPolicy`); a request that keeps
  crashing is isolated (run solo, never re-batched with healthy
  traffic) before it is finally rejected;
* **escalates capacity per batch tier** on a drop-rate breach
  (``escalate_capacity`` on that signature's tier only — one hungry
  scenario never inflates every other tenant's budgets);
* **quarantines poison**: a request that fails health checks
  ``max_health_failures`` times is terminally rejected with its
  :class:`~repro.core.health.SimulationHealthError` attached;
* **degrades gracefully** under pressure: past the soft queue watermark,
  new admissions drop per-neuron probes (raster/voltage) for scalar ones
  and run with shorter chunks (tighter deadline enforcement) *before*
  the hard limit starts shedding.

Every admission, shed, batch, retry, quarantine, deadline and
degradation decision streams through the ambient :mod:`repro.obs`
session (``serve_*`` event kinds in ``schema.json``), and an always-on
:class:`~repro.obs.MetricsRegistry` keeps the counters and latency
percentiles that ``benchmarks/bench_serving.py`` turns into the
``BENCH_serving.json`` trajectory.  See ``docs/serving.md``.
"""

from __future__ import annotations

import dataclasses
import random
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.capacity import escalate_capacity
from repro.core.engine import SimConfig, SimResult, build_synapses
from repro.core.health import (RECOVERABLE_KINDS, BackoffPolicy, HealthConfig,
                               SimulationHealthError, check_chunk,
                               concat_records, lane_snapshots)
from repro.exp import ProbeSpec, build_scenario
from repro.exp.trials import trial_carry


# --------------------------------------------------------------------------
# Request model
# --------------------------------------------------------------------------

#: terminal statuses — every submitted request ends in exactly one of these
COMPLETED = "completed"
REJECTED = "rejected"
QUARANTINED = "quarantined"
TERMINAL = (COMPLETED, REJECTED, QUARANTINED)

QUEUED = "queued"
PENDING = "pending"


@dataclasses.dataclass(eq=False)   # identity equality: results hold arrays
class SimRequest:
    """One simulation request: a named scenario with overrides, a seed,
    a probe selection, and a service contract (deadline, priority).

    ``scenario``/``params`` rather than a raw stimulus pytree is what
    makes admission batching *checkable*: two requests with equal
    ``(scenario, params, t_steps, probes)`` provably share one compile
    signature and differ only in ``seed`` — the vmap axis.  ``params``
    values must be hashable (numbers/strings).

    ``fault_hook(start, stop)`` runs host-side before each chunk of any
    batch containing this request — the injection point the ``faulty``
    exchange wrapper's :meth:`host_supervise` plugs into for tests,
    benchmarks, and CI smokes.
    """

    scenario: str
    t_steps: int
    seed: int = 0
    params: dict = dataclasses.field(default_factory=dict)
    probes: ProbeSpec = ProbeSpec()
    deadline_s: Optional[float] = None     # wall-clock budget from submit
    priority: int = 0                      # higher is served first
    rid: Optional[int] = None              # assigned at submit when None
    fault_hook: Optional[Callable[[int, int], None]] = None

    # -- server-managed ----------------------------------------------------
    status: str = PENDING
    reason: Optional[str] = None           # terminal reason for non-complete
    error: Optional[BaseException] = None  # attached on quarantine/crash
    result: Optional[SimResult] = None
    degraded: bool = False
    solo: bool = False            # failed once: never re-batched with healthy
    attempts: int = 0             # crash retries consumed
    health_failures: int = 0
    submitted_at: Optional[float] = None
    finished_at: Optional[float] = None
    not_before: float = 0.0       # backoff gate (server clock)
    _order: int = 0               # FIFO tiebreak within a priority class

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL

    @property
    def latency_s(self) -> Optional[float]:
        if self.finished_at is None or self.submitted_at is None:
            return None
        return self.finished_at - self.submitted_at


@dataclasses.dataclass(frozen=True)
class SimServeConfig:
    """Serving policy knobs (the failure taxonomy lives in
    docs/serving.md).  ``degrade_queue_depth=None`` disables the
    degradation ladder; ``health=None`` disables sentinels (then only
    deadlines and crash retries protect the server)."""

    max_queue: int = 64            # hard admission limit (then shed)
    max_batch: int = 8             # vmap lanes per tick
    chunk_steps: int = 50          # supervision granularity
    degraded_chunk_steps: int = 20
    degrade_queue_depth: Optional[int] = None   # soft watermark
    default_deadline_s: Optional[float] = None  # applied when request has none
    max_retries: int = 2           # crash re-runs per request
    max_health_failures: int = 2   # then quarantine
    max_escalations: int = 2       # capacity bumps per signature tier
    health: Optional[HealthConfig] = HealthConfig()
    backoff: BackoffPolicy = BackoffPolicy(base_s=0.05, cap_s=5.0)


class _CapacityBreach(Exception):
    """Internal: a recoverable drop-rate breach inside a batch — handled
    at the batch tier (escalate + requeue), never surfaced to callers."""

    def __init__(self, err: SimulationHealthError, rid):
        super().__init__(str(err))
        self.err = err
        self.rid = rid


class _HookCrash(Exception):
    """Internal: a crash raised by one request's ``fault_hook`` — unlike
    a crash from the scan itself, it is attributable, so only the culprit
    pays the retry/isolation cost and its batch-mates requeue free."""

    def __init__(self, err: BaseException, rid):
        super().__init__(str(err))
        self.err = err
        self.rid = rid


def _degrade_probes(p: ProbeSpec) -> ProbeSpec:
    """Coarsen a probe spec under load: per-neuron streams (raster,
    voltage traces) collapse into the scalar population rate; scalar
    streams survive.  Records stay cheap, the answer stays useful."""
    return ProbeSpec(raster=False, voltage=(),
                     pop_rate=p.pop_rate or p.raster or bool(p.voltage),
                     drops=p.drops)


def _lane_result(carry, records: dict, b: int) -> SimResult:
    """Slice lane ``b`` out of a batched carry + records: the SimResult
    this request would have gotten from a solo ``simulate()`` call."""
    recs = {k: v[b] for k, v in records.items()}
    return SimResult(counts=carry.counts[b],
                     state=jax.tree.map(lambda x: x[b], carry.lif),
                     dropped=carry.dropped[b],
                     raster=recs.get("raster"),
                     records=recs,
                     stats={k: v[b] for k, v in carry.stats.items()})


class SimServer:
    """Admission, batching, and supervision over one connectome.

    Synchronous tick loop (the repo's serving idiom — the host only
    moves requests in and results out; all simulation state is device
    resident): :meth:`submit` applies admission control, :meth:`tick`
    serves one batch, :meth:`run` drains a workload to all-terminal.
    ``clock``/``sleep``/``rng`` are injectable for deterministic tests.
    """

    def __init__(self, c, cfg: SimConfig,
                 serve: SimServeConfig = SimServeConfig(), *,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep,
                 rng: Optional[random.Random] = None):
        self.c = c
        # in-scan sentinels are the quarantine substrate: the server's
        # health config rides on the sim config (explicit cfg.health wins)
        if cfg.health is None and serve.health is not None:
            cfg = dataclasses.replace(cfg, health=serve.health)
        self.cfg = cfg
        self.serve = serve
        self.clock = clock
        self.sleep = sleep
        self.rng = rng if rng is not None else random.Random(0)
        self.metrics = obs.MetricsRegistry()
        self._queue: list[SimRequest] = []
        self._seq = 0
        self._next_rid = 0
        self._syn_cache: dict[SimConfig, Any] = {}
        self._stim_cache: dict[tuple, Any] = {}
        self._capacity: dict[tuple, Any] = {}      # per-tier escalations
        self._escalations: dict[tuple, int] = {}
        self._latencies: list[float] = []

    # -- admission ---------------------------------------------------------

    def submit(self, req: SimRequest) -> SimRequest:
        """Admission control: assign an rid, shed on overflow, degrade
        under pressure, enqueue otherwise.  Returns the request; a shed
        request is already terminal (``rejected`` / ``queue_full``)."""
        if req.rid is None:
            req.rid = self._next_rid
        self._next_rid = max(self._next_rid, req.rid) + 1
        req.submitted_at = self.clock()
        self.metrics.inc("serving.submitted")
        if len(self._queue) >= self.serve.max_queue:
            self.metrics.inc("serving.shed")
            self._emit("serve_shed", rid=req.rid, reason="queue_full")
            self._finish(req, REJECTED, reason="queue_full")
            return req
        soft = self.serve.degrade_queue_depth
        if soft is not None and len(self._queue) >= soft:
            degraded = _degrade_probes(req.probes)
            if degraded != req.probes or not req.degraded:
                req.probes = degraded
                req.degraded = True
                self.metrics.inc("serving.degraded")
                self._emit("serve_degrade", rid=req.rid, what="probes+chunk",
                           queue_depth=len(self._queue))
        req.status = QUEUED
        self._seq += 1
        req._order = self._seq
        self._queue.append(req)
        self.metrics.inc("serving.admitted")
        self._emit("serve_admit", rid=req.rid, queue_depth=len(self._queue),
                   priority=req.priority, degraded=req.degraded)
        return req

    # -- scheduling --------------------------------------------------------

    def _signature(self, r: SimRequest) -> tuple:
        return (r.scenario, tuple(sorted(r.params.items())), r.t_steps,
                r.probes, r.degraded)

    def _deadline(self, r: SimRequest) -> Optional[float]:
        return (r.deadline_s if r.deadline_s is not None
                else self.serve.default_deadline_s)

    def _expired(self, r: SimRequest, now: float) -> bool:
        dl = self._deadline(r)
        return dl is not None and now - r.submitted_at > dl

    def tick(self) -> list[SimRequest]:
        """One scheduling round: shed already-expired queue entries, pick
        the highest-priority eligible request, pack every compatible
        (same-signature, non-isolated) request up to ``max_batch`` into
        one vmapped chunked scan, and settle the outcomes.  Returns the
        requests that reached a terminal state this round."""
        now = self.clock()
        finished: list[SimRequest] = []
        still: list[SimRequest] = []
        for r in self._queue:
            if self._expired(r, now):
                self._expire(r, step=0)
                finished.append(r)
            else:
                still.append(r)
        self._queue = still
        ready = [r for r in self._queue if r.not_before <= now]
        if not ready:
            return finished
        ready.sort(key=lambda r: (-r.priority, r._order))
        head = ready[0]
        if head.solo:
            batch = [head]
        else:
            sig = self._signature(head)
            batch = [r for r in ready
                     if not r.solo and self._signature(r) == sig]
            batch = batch[: self.serve.max_batch]
        for r in batch:
            self._queue.remove(r)
        finished.extend(self._run_batch(batch))
        return finished

    def run(self, requests=None, max_ticks: int = 10_000
            ) -> list[SimRequest]:
        """Serve a workload until every request is terminal.  The
        ``max_ticks`` backstop rejects leftovers with
        ``reason="server_stopped"`` rather than dropping them — callers
        can always account for every submission."""
        requests = list(requests) if requests is not None else []
        for r in requests:
            if r.status == PENDING:
                self.submit(r)
        seen = list(requests)
        ticks = 0
        while self._queue and ticks < max_ticks:
            done = self.tick()
            for r in done:
                if r not in seen:
                    seen.append(r)
            if self._queue:
                wait = min(r.not_before for r in self._queue) - self.clock()
                if wait > 0:
                    # every queued request is backing off — sleep to the
                    # earliest retry gate instead of spinning
                    self.sleep(wait)
            ticks += 1
        for r in self._queue:
            self._finish(r, REJECTED, reason="server_stopped")
        self._queue = []
        return seen

    # -- batch execution ---------------------------------------------------

    def _cfg_for(self, sig: tuple) -> SimConfig:
        cap = self._capacity.get(sig)
        return (dataclasses.replace(self.cfg, capacity=cap)
                if cap is not None else self.cfg)

    def _syn(self, cfg: SimConfig):
        if cfg not in self._syn_cache:
            self._syn_cache[cfg] = build_synapses(self.c, cfg)
        return self._syn_cache[cfg]

    def _stimulus(self, r: SimRequest):
        key = (r.scenario, tuple(sorted(r.params.items())))
        if key not in self._stim_cache:
            self._stim_cache[key] = build_scenario(
                r.scenario, self.c, self.cfg, **r.params)
        return self._stim_cache[key]

    def _run_batch(self, batch: list[SimRequest]) -> list[SimRequest]:
        sig = self._signature(batch[0])
        cfg = self._cfg_for(sig)
        chunk = (self.serve.degraded_chunk_steps if batch[0].degraded
                 else self.serve.chunk_steps)
        t_steps = batch[0].t_steps
        self.metrics.inc("serving.batches")
        self._emit("serve_batch", size=len(batch), signature=_sig_str(sig),
                   chunk_steps=chunk, t_steps=t_steps,
                   rids=[r.rid for r in batch])
        try:
            stim = self._stimulus(batch[0])
            with obs.span("serve_batch", size=len(batch)):
                lanes = self._execute(batch, stim, cfg, batch[0].probes,
                                      t_steps, chunk)
        except _CapacityBreach as cb:
            return self._escalate(sig, batch, cb)
        except _HookCrash as hc:
            return self._crashed(batch, hc.err, culprit=hc.rid)
        except SimulationHealthError:
            raise   # programming error: lane attribution must catch these
        except Exception as e:  # noqa: BLE001 — crash taxonomy, see below
            return self._crashed(batch, e)
        finished = []
        for r, outcome in zip(batch, lanes):
            kind = outcome[0]
            if kind == "done":
                self._finish(r, COMPLETED, result=outcome[1])
                finished.append(r)
            elif kind == "deadline":
                self._expire(r, step=outcome[1])
                finished.append(r)
            else:   # poison
                done = self._poisoned(r, outcome[1])
                if done:
                    finished.append(r)
        return finished

    def _execute(self, batch, stim, cfg: SimConfig, probes, t_steps: int,
                 chunk_steps: int):
        """Drive one packed batch as a chunked vmapped scan.  Returns one
        outcome per lane: ``("done", SimResult)`` / ``("deadline", step)``
        / ``("poison", SimulationHealthError)``.  Raises
        :class:`_CapacityBreach` on a recoverable drop-rate breach and
        lets crashes (RuntimeError et al.) propagate to the retry path."""
        from repro.core.engine import _run_scan_trials
        n = self.c.n
        syn = self._syn(cfg)
        carry, _ = trial_carry(n, cfg, stim, [r.seed for r in batch])
        hc = cfg.health
        prev = lane_snapshots(0, carry) if hc is not None else None
        out: list[Optional[tuple]] = [None] * len(batch)
        chunks: list[dict] = []
        s = 0
        while s < t_steps:
            k = min(chunk_steps, t_steps - s)
            for r in batch:
                if r.fault_hook is not None:
                    try:
                        r.fault_hook(s, s + k)
                    except Exception as e:   # noqa: BLE001 — attributed
                        raise _HookCrash(e, r.rid) from e
            carry, rec = _run_scan_trials(syn, carry, stim, cfg, probes,
                                          k, n, jnp.int32(s))
            self.metrics.inc("serving.chunks")
            s += k
            chunks.append(rec)
            now = self.clock()
            snaps = lane_snapshots(s, carry) if hc is not None else None
            for b, r in enumerate(batch):
                if out[b] is not None:
                    continue
                if self._expired(r, now):
                    # enforced at the chunk boundary: the lane stops
                    # mattering here even though the batch may continue
                    out[b] = ("deadline", s)
                    continue
                if hc is None:
                    continue
                try:
                    check_chunk(prev[b], snaps[b], hc, n=n,
                                dt_ms=cfg.params.dt)
                except SimulationHealthError as e:
                    if e.kind in RECOVERABLE_KINDS:
                        # under-provisioned batch tier, not a sick lane
                        raise _CapacityBreach(e, r.rid) from None
                    out[b] = ("poison", e)
            if snaps is not None:
                prev = snaps
            if all(o is not None for o in out):
                break   # nobody left to serve — stop burning device time
        records = concat_records(chunks, axis=1)
        return [out[b] if out[b] is not None
                else ("done", _lane_result(carry, records, b))
                for b, r in enumerate(batch)]

    # -- outcome handling --------------------------------------------------

    def _requeue(self, r: SimRequest, backoff_s: float) -> None:
        r.status = QUEUED
        r.not_before = self.clock() + backoff_s
        self._seq += 1
        r._order = self._seq
        self._queue.append(r)

    def _crashed(self, batch: list[SimRequest], e: BaseException,
                 culprit=None) -> list[SimRequest]:
        """Transient-crash policy: retry with jittered exponential
        backoff, and keep crashers away from healthy traffic.  When the
        crash is attributable (``culprit`` — a request's own fault hook
        raised), only that request pays: it is isolated (solo)
        immediately and its batch-mates requeue with no attempt charged
        and no backoff.  An unattributable crash (the scan itself died)
        charges every member; a member that has crashed twice is
        isolated.  Retries exhausted -> rejected, error attached."""
        finished = []
        delays = []
        retried = []
        for r in batch:
            blamed = culprit is None or r.rid == culprit
            if not blamed:
                self._requeue(r, 0.0)
                continue
            r.attempts += 1
            if r.attempts > self.serve.max_retries:
                r.error = e
                self._finish(r, REJECTED, reason="crash")
                finished.append(r)
                continue
            if culprit is not None or r.attempts >= 2:
                r.solo = True
            d = self.serve.backoff.delay(r.attempts, self.rng)
            delays.append(d)
            retried.append(r)
            self._requeue(r, d)
        self.metrics.inc("serving.retries", len(retried))
        if retried:
            self._emit("serve_retry", reason=f"crash:{type(e).__name__}",
                       backoff_s=round(max(delays), 6),
                       rids=[r.rid for r in retried],
                       attempt=max(r.attempts for r in retried),
                       solo=any(r.solo for r in retried))
        return finished

    def _escalate(self, sig: tuple, batch: list[SimRequest],
                  cb: _CapacityBreach) -> list[SimRequest]:
        """Drop-rate breach: escalate THIS signature tier's capacity and
        retry the whole batch (seeds unchanged, so the accepted re-run is
        still bit-faithful); tiers are independent, so one hungry
        scenario never inflates every tenant's budgets."""
        n_esc = self._escalations.get(sig, 0) + 1
        if n_esc > self.serve.max_escalations:
            for r in batch:
                r.error = cb.err
                self._finish(r, REJECTED, reason="capacity")
            return list(batch)
        self._escalations[sig] = n_esc
        base = self._capacity.get(sig) or self.cfg.capacity
        self._capacity[sig] = escalate_capacity(base)
        self.metrics.inc("serving.escalations")
        d = self.serve.backoff.delay(n_esc, self.rng)
        for r in batch:
            self._requeue(r, d)
        self._emit("serve_retry", reason="drop_rate",
                   backoff_s=round(d, 6), rids=[r.rid for r in batch],
                   attempt=n_esc, solo=False)
        return []

    def _poisoned(self, r: SimRequest, e: SimulationHealthError) -> bool:
        """Poison policy: first failure isolates the request (solo —
        never re-batched with healthy traffic); ``max_health_failures``
        failures quarantine it with the health error attached."""
        r.health_failures += 1
        if r.health_failures >= self.serve.max_health_failures:
            r.error = e
            self._emit("serve_quarantine", rid=r.rid, error=str(e),
                       step=e.step)
            self._finish(r, QUARANTINED, reason=e.kind)
            return True
        r.solo = True
        d = self.serve.backoff.delay(r.health_failures, self.rng)
        self.metrics.inc("serving.retries")
        self._requeue(r, d)
        self._emit("serve_retry", reason=f"health:{e.kind}",
                   backoff_s=round(d, 6), rids=[r.rid],
                   attempt=r.health_failures, solo=True)
        return False

    def _expire(self, r: SimRequest, step: int) -> None:
        self.metrics.inc("serving.deadline_expired")
        self._emit("serve_deadline", rid=r.rid, step=step,
                   deadline_s=self._deadline(r))
        self._finish(r, REJECTED, reason="deadline")

    def _finish(self, r: SimRequest, status: str, reason=None,
                result=None) -> None:
        r.status = status
        r.reason = reason
        r.result = result
        r.finished_at = self.clock()
        self.metrics.inc(f"serving.{status}")
        if status == COMPLETED and r.latency_s is not None:
            self._latencies.append(r.latency_s)
        self._emit("serve_request_end", rid=r.rid, status=status,
                   reason=reason, wall_s=round(r.latency_s or 0.0, 6))

    # -- observability -----------------------------------------------------

    def _emit(self, type_: str, **fields) -> None:
        tele = obs.active()
        if tele is not None:
            tele.emit(type_, **{k: v for k, v in fields.items()
                                if v is not None})

    def stats(self) -> dict:
        """Point-in-time snapshot: queue depth, terminal-state counters,
        retry/escalation/degradation accounting, and completed-request
        latency percentiles (the bench rows)."""
        c = self.metrics.counters()
        lat = np.asarray(sorted(self._latencies), np.float64)
        pct = (lambda q: float(np.percentile(lat, q)) if lat.size else None)
        out = {
            "queue_depth": len(self._queue),
            "latency_p50_s": pct(50),
            "latency_p99_s": pct(99),
            "escalated_tiers": len(self._capacity),
        }
        for k in ("submitted", "admitted", "shed", "completed", "rejected",
                  "quarantined", "retries", "escalations", "batches",
                  "chunks", "degraded", "deadline_expired"):
            out[k] = int(c.get(f"serving.{k}", 0))
        tele = obs.active()
        if tele is not None:
            out["compile_cache"] = tele.metrics.compile_snapshot()
        return out


def _sig_str(sig: tuple) -> str:
    scenario, params, t_steps, probes, degraded = sig
    kv = ",".join(f"{k}={v}" for k, v in params)
    return (f"{scenario}({kv})/T{t_steps}"
            + ("/degraded" if degraded else ""))


__all__ = ["COMPLETED", "QUARANTINED", "QUEUED", "REJECTED", "TERMINAL",
           "SimRequest", "SimServeConfig", "SimServer"]
