from .train_step import TrainState, make_train_step
from .checkpoint import save_checkpoint, restore_checkpoint, latest_step
from .fault import FaultConfig, StragglerDetector, simulate_failures

__all__ = ["TrainState", "make_train_step", "save_checkpoint",
           "restore_checkpoint", "latest_step", "FaultConfig",
           "StragglerDetector", "simulate_failures"]
