"""Sharded npz checkpointing with elastic restore.

Layout: <dir>/step_<N>/
  manifest.json            — flat-key -> {shape, dtype}, plus user metadata
  arrays.npz               — one entry per flattened pytree leaf

Restore never assumes the saving mesh: leaves are loaded on host and
device_put with the *destination* sharding, so a job restarted on a
different topology (elastic downscale: 2 pods -> 1 pod) resharding is a
single device_put per leaf.  Saves are atomic (tmpdir + rename) so a crash
mid-save never corrupts the latest complete step, and can run on a
background thread (async_save=True) to overlap with training/simulation —
the returned :class:`CheckpointHandle` MUST be joined (the supervisor
joins at chunk boundaries and before exit) so a fast exit can never drop
the newest checkpoint, and join re-raises any write-thread failure
instead of losing it.

Restores are shape- AND dtype-checked against the target tree: a Q19.12
int32 simulation carry restored into a float target would otherwise
silently cast and corrupt the bit-faithful fixed-point path.
"""

from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(p) for p in path)
        out[key] = leaf
    return out


class CheckpointHandle:
    """Joinable async-save handle.  ``join()`` blocks until the write
    finishes and re-raises anything the write thread raised — an async
    checkpoint failure must surface at the supervision point, not vanish
    with a daemon thread."""

    def __init__(self, fn):
        self._error: Optional[BaseException] = None

        def guarded():
            try:
                fn()
            except BaseException as e:  # noqa: BLE001 — re-raised in join
                self._error = e

        self._thread = threading.Thread(target=guarded, daemon=True)
        self._thread.start()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError("checkpoint write still running")
        if self._error is not None:
            raise self._error

    def done(self) -> bool:
        return not self._thread.is_alive()


def save_checkpoint(directory: str, step: int, tree, metadata: dict | None
                    = None, async_save: bool = False
                    ) -> Optional[CheckpointHandle]:
    """Blocking by default; ``async_save`` runs the npz write on a
    background thread after the host transfer (device->host copy happens
    synchronously so the saved state is the state at call time) and
    returns a :class:`CheckpointHandle` the caller must join."""
    flat = _flatten_with_paths(tree)
    host = {k: np.asarray(v) for k, v in flat.items()}

    def write():
        tmp = os.path.join(directory, f".tmp_step_{step}")
        final = os.path.join(directory, f"step_{step}")
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                     for k, v in host.items()},
            "metadata": metadata or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_save:
        return CheckpointHandle(write)
    write()
    return None


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def read_checkpoint_arrays(directory: str, step: int
                           ) -> tuple[dict[str, np.ndarray], dict]:
    """Raw flat-key -> host array dict + user metadata, no target tree
    needed — for callers that reconstruct variable-shape subtrees (e.g.
    the simulation checkpointer's records-so-far, whose time axis grows
    every chunk) from the manifest instead of a template."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    return {k: z[k] for k in z.files}, manifest["metadata"]


def restore_checkpoint(directory: str, step: int, target_tree,
                       shardings=None):
    """target_tree: pytree with the same structure (values or
    ShapeDtypeStructs).  shardings: optional matching tree of NamedSharding
    — the elastic-reshard path (device_put onto the *current* mesh).

    Leaves in the checkpoint that the target tree does not reference are
    ignored (a sub-tree restore); every referenced leaf is shape- and
    dtype-checked against the target."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    z = np.load(os.path.join(path, "arrays.npz"))
    flat_target, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    flat_shardings = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(flat_target))
    leaves = []
    for (p, tgt), shd in zip(flat_target, flat_shardings):
        key = "/".join(str(q) for q in p)
        arr = z[key]
        if tuple(arr.shape) != tuple(tgt.shape):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs target {tgt.shape}")
        tgt_dtype = getattr(tgt, "dtype", None)
        if tgt_dtype is not None and np.dtype(arr.dtype) != np.dtype(tgt_dtype):
            # a silent cast here corrupts the bit-faithful Q19.12 path
            # (int32 carry -> float target loses the fixed-point contract)
            raise ValueError(f"dtype mismatch for {key}: "
                             f"ckpt {arr.dtype} vs target {tgt_dtype}")
        leaves.append(jax.device_put(arr, shd) if shd is not None
                      else jax.numpy.asarray(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target_tree), leaves)
    return tree, manifest["metadata"]
