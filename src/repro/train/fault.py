"""Fault tolerance: straggler detection + failure simulation + elastic
recovery policy.

On a 1000+-node fleet the loop must survive (a) node loss — recover from
the last checkpoint, possibly on a smaller mesh (elastic downscale), and
(b) stragglers — detect per-step time outliers and react.  This module
provides the host-side machinery; the integration lives in
launch/train.py and is exercised by tests/test_fault.py with *injected*
failures (the only kind available without hardware).
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Optional

import numpy as np


@dataclasses.dataclass
class FaultConfig:
    fail_at_steps: tuple = ()        # injected hard failures (raise)
    straggle_at_steps: tuple = ()    # injected slow steps
    straggle_factor: float = 5.0
    z_threshold: float = 3.0         # straggler detection z-score
    window: int = 32


class StragglerDetector:
    """Rolling z-score over per-step wall times.  On real fleets the same
    statistic runs per-host over collective-completion times; here it runs
    over the single-process step time (the algorithm is what is tested)."""

    def __init__(self, window: int = 32, z_threshold: float = 3.0):
        self.times = deque(maxlen=window)
        self.z = z_threshold
        self.flagged: list[tuple[int, float, float]] = []

    def observe(self, step: int, dt: float) -> bool:
        is_straggler = False
        if len(self.times) >= 8:
            mu = float(np.mean(self.times))
            sd = float(np.std(self.times)) + 1e-9
            if (dt - mu) / sd > self.z:
                is_straggler = True
                self.flagged.append((step, dt, mu))
        # straggler steps are excluded from the baseline window
        if not is_straggler:
            self.times.append(dt)
        return is_straggler


class InjectedFailure(RuntimeError):
    pass


def simulate_failures(step: int, cfg: FaultConfig):
    """Call at the top of each step; raises InjectedFailure on configured
    steps and sleeps on configured straggle steps."""
    if step in cfg.fail_at_steps:
        raise InjectedFailure(f"injected node failure at step {step}")
    if step in cfg.straggle_at_steps:
        time.sleep(0.05 * cfg.straggle_factor)


def run_with_recovery(run_fn: Callable[[Optional[int]], int],
                      max_restarts: int = 3,
                      checkpoint_dir: Optional[str] = None) -> int:
    """Supervisor loop: run_fn(resume_step) runs until completion or raises;
    on failure it is restarted from the latest checkpoint.  Returns the
    final step.  run_fn returns the last completed step.

    With ``checkpoint_dir``, the restart signal is the explicit
    ``latest_step(checkpoint_dir)`` (None when no checkpoint exists yet —
    a cold restart); without it, the legacy ``-1`` sentinel is passed and
    run_fn must resolve the latest checkpoint itself.  The generalized
    simulation supervisor (crash recovery + health-breach escalation)
    is :func:`repro.core.health.run_resilient`."""
    restarts = 0
    resume = None
    while True:
        try:
            return run_fn(resume)
        except InjectedFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            if checkpoint_dir is not None:
                from .checkpoint import latest_step
                resume = latest_step(checkpoint_dir)
            else:
                resume = -1   # legacy signal: reload latest checkpoint
