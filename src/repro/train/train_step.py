"""Train-step builder: value_and_grad + microbatched gradient accumulation
+ AdamW, with optional int8 error-feedback gradient compression on the pod
(DCN) boundary.

Microbatching is the activation-memory lever at scale: the global batch is
split into M microbatches scanned sequentially with gradient accumulation,
so live activation memory is 1/M of the full-batch remat footprint (stored
scan residuals: L x B/M x S x d_model).  M is a per-(arch x shape) config
surfaced to the dry-run and the §Perf log.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn
from repro.optim import AdamW, error_feedback_update


class TrainState(NamedTuple):
    params: Any
    opt: Any
    residual: Any      # error-feedback residuals (None when compression off)


def init_train_state(params, optimizer: AdamW, compress: bool = False
                     ) -> TrainState:
    residual = (jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params) if compress else None)
    return TrainState(params=params, opt=optimizer.init(params),
                      residual=residual)


def make_train_step(cfg, optimizer: AdamW, microbatches: int = 1,
                    compress_grads: bool = False):
    """Returns step(state, batch) -> (state, metrics).

    batch leaves: [B, ...] with B divisible by `microbatches`.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch, cfg)

    def step(state: TrainState, batch):
        params = state.params
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            def reshape(x):
                B = x.shape[0]
                return x.reshape(microbatches, B // microbatches,
                                 *x.shape[1:])
            mbs = jax.tree.map(reshape, batch)

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, g_acc, g)), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0.0), g0),
                                            mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        residual = state.residual
        if compress_grads:
            # two maps (XLA CSEs the duplicate work under jit) — avoids
            # tuple-leaf trees colliding with tuple containers in params
            new_grads = jax.tree.map(
                lambda g, r: error_feedback_update(g, r)[0], grads, residual)
            residual = jax.tree.map(
                lambda g, r: error_feedback_update(g, r)[1], grads, residual)
            grads = new_grads

        updates, opt, gnorm = optimizer.update(grads, state.opt, params)
        params = AdamW.apply_updates(params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": (optimizer.lr(opt.step) if callable(optimizer.lr)
                          else jnp.float32(optimizer.lr))}
        return TrainState(params=params, opt=opt, residual=residual), metrics

    return step
