"""Resilience layer: in-scan health sentinels, chunked supervised scans,
checkpoint/resume, and the crash/escalation supervisor.

The paper's headline result is a *long-running* whole-brain simulation
whose correctness is established statistically (Brian2 ↔ STACS ↔ Loihi
parity) — which means a silent NaN, a Q19.12 saturation cascade, or an
uncounted capacity overflow partway through a run quietly invalidates the
science.  This module makes those failure modes observable and
survivable without touching the scan's arithmetic:

* **Sentinels** (:func:`health_stats_init` / :func:`health_step_stats`)
  are scalar counters accumulated *inside* the jitted scan at near-zero
  cost — non-finite v/g entries on the float path, saturation-at-clip on
  the int32 Q19.12 path — and surfaced through ``SimResult.stats`` /
  ``DistResult.stats`` next to the scheme counters.
* **Chunked supervision** (:func:`run_chunked`): a T-step run becomes
  ceil(T/K) reuses of one compiled K-step program with the carry threaded
  through host-side — bit-identical to the monolithic scan (the step
  index is offset by a *traced* ``t0``, so every chunk reuses the same
  program) — giving the host a supervision point every K steps where
  :class:`HealthConfig` thresholds are checked against the per-chunk
  counter deltas.
* **Checkpoint/resume** (:class:`SimCheckpointer`): at chunk boundaries
  the carry (and records-so-far) are written through
  :mod:`repro.train.checkpoint` (atomic tmp+rename, optional async with a
  joinable handle), so a killed run resumes from ``latest_step`` and
  reproduces the uninterrupted run's raster/records bit-for-bit.
* **Supervision policy** (:func:`run_resilient`, generalizing
  :func:`repro.train.fault.run_with_recovery` beyond its ``resume=-1``
  magic value): poison (NaN / saturation / rate-envelope) raises
  :class:`SimulationHealthError` naming the step and counter; a crash
  restarts from the latest checkpoint; a drop-rate breach re-derives an
  escalated :class:`~repro.core.capacity.CapacityConfig` and resumes
  from the last *healthy* checkpoint — drops stay exactly accounted
  throughout because the breached chunk is never checkpointed.

Fault injection for exercising all of this without hardware lives in
:mod:`repro.core.exchange.faulty`.  See ``docs/resilience.md``.
"""

from __future__ import annotations

import dataclasses
import random
import re
import time
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .capacity import CapacityConfig


# --------------------------------------------------------------------------
# Config + error
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Thresholds checked at each chunk boundary (host-side, against the
    per-chunk deltas of the in-scan counters).  Hashable — it rides on
    :class:`~repro.core.engine.SimConfig` and is part of the jit cache
    key, so enabling health retraces but never changes scan semantics.

    ``max_nonfinite`` / ``max_saturated`` bound the *poison* counters
    (float non-finite v/g entries; Q19.12 |x| within ``sat_margin_bits``
    of the int32 limit — the saturation-at-clip regime where fixed-point
    arithmetic silently corrupts, per Dey & Dimitrov).  ``max_drop_rate``
    bounds dropped synapse events per step (the recoverable breach — see
    :func:`run_resilient`'s escalation policy).  ``rate_lo_hz`` /
    ``rate_hi_hz`` bound the per-chunk mean population rate (a dead or
    runaway network is a health event even when every number is finite).
    """

    max_nonfinite: int = 0
    max_saturated: int = 0
    sat_margin_bits: int = 2       # |x| >= 2**(31 - margin) counts saturated
    max_drop_rate: Optional[float] = None   # dropped synapse events / step
    rate_lo_hz: Optional[float] = None      # per-chunk mean pop rate bounds
    rate_hi_hz: Optional[float] = None


class SimulationHealthError(RuntimeError):
    """A health threshold was breached at a chunk boundary.

    ``kind`` is the counter (``nonfinite`` / ``saturated`` /
    ``drop_rate`` / ``rate_envelope``), ``step`` the simulation step of
    the chunk boundary that detected it, ``value`` the offending
    per-chunk measurement.  Poison kinds are deterministic corruption —
    restarting reproduces them — so :func:`run_resilient` re-raises
    them; ``drop_rate`` is recoverable by capacity escalation.
    """

    def __init__(self, kind: str, step: int, value, threshold):
        self.kind, self.step, self.value, self.threshold = \
            kind, step, value, threshold
        super().__init__(
            f"health breach at step {step}: {kind}={value} "
            f"(threshold {threshold})")


#: kinds that escalation can fix (everything else is poison)
RECOVERABLE_KINDS = ("drop_rate",)


@dataclasses.dataclass(frozen=True)
class BackoffPolicy:
    """Jittered exponential backoff for supervision retry loops.

    A crash-looping run must not hot-spin: every restart or escalation
    waits ``base_s * factor**(attempt-1)`` seconds, capped at ``cap_s``,
    with a multiplicative ±``jitter`` fraction so a fleet of supervised
    runs restarting off the same incident doesn't re-stampede in sync.
    Consumed by :func:`run_resilient` and the serving layer
    (:mod:`repro.serving.sim`); the chosen delay is surfaced on the
    corresponding telemetry event (``backoff_s``).
    """

    base_s: float = 0.1
    factor: float = 2.0
    cap_s: float = 30.0
    jitter: float = 0.25     # fraction of the delay, uniform in [-j, +j]

    def delay(self, attempt: int, rng=None) -> float:
        """Delay in seconds before retry number ``attempt`` (1-based).
        ``rng`` is anything with ``.random()`` (default: the ``random``
        module) — pass a seeded ``random.Random`` for determinism."""
        d = min(self.base_s * self.factor ** max(0, attempt - 1), self.cap_s)
        if self.jitter:
            u = (rng if rng is not None else random).random()
            d *= 1.0 + self.jitter * (2.0 * u - 1.0)
        return max(0.0, d)


# --------------------------------------------------------------------------
# In-scan sentinels
# --------------------------------------------------------------------------

def health_stats_init(sim) -> dict:
    """Zero-initialized sentinel counters for ``sim`` (merged into the
    scan carry's ``stats`` dict next to the exchange-scheme counters).
    Empty when ``sim.health`` is None — the counters then cost nothing
    and the carry pytree is unchanged."""
    if getattr(sim, "health", None) is None:
        return {}
    if sim.fixed_point:
        return {"h_saturated": jnp.int32(0)}
    return {"h_nonfinite": jnp.int32(0)}


def health_step_stats(lif, sim) -> dict:
    """Per-step sentinel increments, traced inside the scan body.

    Float path: count non-finite entries of v and g.  Q19.12 path: count
    entries within ``sat_margin_bits`` of the int32 limit — int32 wraps
    rather than clips in jnp, so the margin catches the cascade *before*
    wraparound makes it unattributable."""
    hc = getattr(sim, "health", None)
    if hc is None:
        return {}
    if sim.fixed_point:
        thresh = jnp.int32(1 << (31 - hc.sat_margin_bits))
        sat = (jnp.sum((lif.v >= thresh) | (lif.v <= -thresh))
               + jnp.sum((lif.g >= thresh) | (lif.g <= -thresh)))
        return {"h_saturated": sat.astype(jnp.int32)}
    nf = jnp.sum(~jnp.isfinite(lif.v)) + jnp.sum(~jnp.isfinite(lif.g))
    return {"h_nonfinite": nf.astype(jnp.int32)}


# --------------------------------------------------------------------------
# Chunk-boundary supervision
# --------------------------------------------------------------------------

class HealthSnapshot(NamedTuple):
    """Host-side reduction of the carry's cumulative counters at a chunk
    boundary.  Works on both the monolithic carry and the
    partition-stacked (or trial-batched) distributed carry — every field
    is a plain sum over all leading axes."""

    step: int
    spikes: int
    dropped: int
    nonfinite: int
    saturated: int


@jax.jit
def _sum_leaves(tree):
    return jax.tree_util.tree_map(lambda v: jnp.asarray(v).sum(), tree)


def carry_counters(carry) -> dict:
    """Host-side reduction of every cumulative carry counter to a flat
    name -> int dict (spikes, drops, scheme stats, health sentinels) —
    the per-chunk-boundary telemetry record's payload.  Works on the
    monolithic, partition-stacked, and trial-batched carries alike
    (plain sums over all leading axes).  O(counters), not O(n): the only
    per-neuron reduction (``counts.sum()``) happens on device, and the
    whole dict reduces in ONE jitted dispatch + ONE transfer so the
    per-chunk telemetry cost doesn't scale with the counter count."""
    sums = jax.device_get(_sum_leaves(
        {"spikes": carry.counts, "dropped": carry.dropped, **carry.stats}))
    return {k: int(v) for k, v in sums.items()}


@jax.jit
def _sum_lane_leaves(tree):
    return jax.tree_util.tree_map(
        lambda v: jnp.asarray(v).reshape(jnp.asarray(v).shape[0], -1)
        .sum(axis=1), tree)


def lane_snapshots(step: int, carry) -> list[HealthSnapshot]:
    """Per-lane :class:`HealthSnapshot` of a trial-batched carry (leaves
    ``[B, ...]``): lane ``b``'s counters reduce over everything *except*
    the leading batch axis, so a poisoned or starved request inside a
    packed batch is attributable to exactly one lane.  One jitted
    dispatch + one transfer for the whole batch — the serving layer's
    per-request health check at every chunk boundary."""
    sums = jax.device_get(_sum_lane_leaves(
        {"spikes": carry.counts, "dropped": carry.dropped, **carry.stats}))
    return [HealthSnapshot(
        step=int(step),
        spikes=int(sums["spikes"][b]),
        dropped=int(sums["dropped"][b]),
        nonfinite=int(sums["h_nonfinite"][b]) if "h_nonfinite" in sums else 0,
        saturated=int(sums["h_saturated"][b]) if "h_saturated" in sums else 0,
    ) for b in range(len(sums["spikes"]))]


def snapshot(step: int, carry) -> HealthSnapshot:
    st = carry.stats
    return HealthSnapshot(
        step=int(step),
        spikes=int(np.asarray(carry.counts).sum()),
        dropped=int(np.asarray(carry.dropped).sum()),
        nonfinite=int(np.asarray(st["h_nonfinite"]).sum())
        if "h_nonfinite" in st else 0,
        saturated=int(np.asarray(st["h_saturated"]).sum())
        if "h_saturated" in st else 0,
    )


def check_chunk(prev: HealthSnapshot, now: HealthSnapshot, hc: HealthConfig,
                *, n: int, dt_ms: float) -> None:
    """Check one chunk's counter deltas against ``hc``; raises
    :class:`SimulationHealthError` naming the step and counter."""
    steps = now.step - prev.step
    if steps <= 0:
        return
    d_nf = now.nonfinite - prev.nonfinite
    if d_nf > hc.max_nonfinite:
        raise SimulationHealthError("nonfinite", now.step, d_nf,
                                    hc.max_nonfinite)
    d_sat = now.saturated - prev.saturated
    if d_sat > hc.max_saturated:
        raise SimulationHealthError("saturated", now.step, d_sat,
                                    hc.max_saturated)
    if hc.max_drop_rate is not None:
        rate = (now.dropped - prev.dropped) / steps
        if rate > hc.max_drop_rate:
            raise SimulationHealthError("drop_rate", now.step, rate,
                                        hc.max_drop_rate)
    if hc.rate_lo_hz is not None or hc.rate_hi_hz is not None:
        hz = (now.spikes - prev.spikes) / (n * steps * dt_ms * 1e-3)
        lo = hc.rate_lo_hz if hc.rate_lo_hz is not None else -np.inf
        hi = hc.rate_hi_hz if hc.rate_hi_hz is not None else np.inf
        if not lo <= hz <= hi:
            raise SimulationHealthError("rate_envelope", now.step,
                                        round(hz, 4), (lo, hi))


# --------------------------------------------------------------------------
# Checkpointing at chunk boundaries
# --------------------------------------------------------------------------

_RECORD_KEY = re.compile(r"^\['records'\]/\['(\w+)'\]$")


class SimCheckpointer:
    """Carry + records-so-far checkpoints through
    :mod:`repro.train.checkpoint` (atomic tmp+rename already handles a
    crash mid-save).  ``async_save`` overlaps the npz write with the next
    chunk; the handle is joined before the next save and at run end, so
    the newest checkpoint can never be dropped by a fast exit."""

    def __init__(self, directory: str, async_save: bool = False,
                 every: int = 1):
        self.directory = str(directory)
        self.async_save = async_save
        self.every = max(1, int(every))
        self._handle = None
        self._saved = 0

    def save(self, step: int, carry, records: dict) -> bool:
        """Returns True when a checkpoint was actually written (the
        ``every`` throttle may skip boundaries)."""
        from repro.train.checkpoint import save_checkpoint
        self._saved += 1
        if self._saved % self.every:
            return False
        self.join()
        self._handle = save_checkpoint(
            self.directory, int(step), {"carry": carry,
                                        "records": dict(records)},
            metadata={"sim_step": int(step)}, async_save=self.async_save)
        return True

    def join(self) -> None:
        if self._handle is not None:
            self._handle.join()
            self._handle = None

    def latest(self) -> Optional[int]:
        from repro.train.checkpoint import latest_step
        return latest_step(self.directory)

    def restore_latest(self, carry_template):
        """-> (carry, records, step) from the newest checkpoint, or None.
        ``carry_template`` supplies structure + shapes + dtypes (the
        restore is shape- AND dtype-checked: a Q19.12 int32 carry can
        never silently cast into a float target)."""
        from repro.train.checkpoint import (read_checkpoint_arrays,
                                            restore_checkpoint)
        step = self.latest()
        if step is None:
            return None
        tree, meta = restore_checkpoint(self.directory, step,
                                        {"carry": carry_template})
        raw, _ = read_checkpoint_arrays(self.directory, step)
        records = {m.group(1): jnp.asarray(v) for k, v in raw.items()
                   if (m := _RECORD_KEY.match(k))}
        return tree["carry"], records, int(meta.get("sim_step", step))


# --------------------------------------------------------------------------
# The chunked driver (shared by simulate() and simulate_distributed())
# --------------------------------------------------------------------------

def concat_records(chunks: list[dict], axis: int) -> dict:
    """Concatenate per-chunk record dicts along the time axis."""
    chunks = [c for c in chunks if c]
    if not chunks:
        return {}
    if len(chunks) == 1:
        return chunks[0]
    return {k: jnp.concatenate([c[k] for c in chunks], axis=axis)
            for k in chunks[0]}


def run_chunked(run_chunk: Callable[[Any, int, int], tuple],
                carry, t_steps: int, chunk_steps: Optional[int], *,
                time_axis: int = 0, health: Optional[HealthConfig] = None,
                n: int = 1, dt_ms: float = 0.1,
                checkpointer: Optional[SimCheckpointer] = None,
                resume: bool = False, host_hook=None):
    """Drive ``ceil(T/K)`` chunked scans with host supervision between
    them: ``run_chunk(carry, start_step, k) -> (carry, records)`` runs one
    K-step compiled program starting at ``start_step``.

    Health thresholds are checked (and raise) *before* the chunk is
    checkpointed, so the last checkpoint on disk is always the last
    *healthy* boundary — the supervisor's escalation resume point.
    ``host_hook(start, stop)`` runs before each chunk (the fault-injection
    scheme's host-side failure/straggler hook).

    When a telemetry session is active (:mod:`repro.obs`), each chunk
    boundary additionally emits one ``chunk`` event — wall time,
    steps/sec, cumulative and per-chunk counter deltas — plus
    ``checkpoint`` and ``health`` events as they happen.  All of it is
    host-side and O(1) per chunk; the scan itself is untouched, so the
    results stay bit-identical with telemetry on or off."""
    chunk_steps = t_steps if not chunk_steps else int(chunk_steps)
    if chunk_steps <= 0:
        raise ValueError(f"chunk_steps must be positive, got {chunk_steps}")
    tele = obs.active()
    start = 0
    chunks: list[dict] = []
    if checkpointer is not None and resume:
        restored = checkpointer.restore_latest(carry)
        if restored is not None:
            carry, saved_records, start = restored
            if saved_records:
                chunks.append(saved_records)
    prev = snapshot(start, carry) if health is not None else None
    prev_counters = carry_counters(carry) if tele is not None else None
    s = start
    while s < t_steps:
        k = min(chunk_steps, t_steps - s)
        if host_hook is not None:
            host_hook(s, s + k)
        with obs.span("chunk", step=s) as sp:
            carry, rec = run_chunk(carry, s, k)
            if tele is not None:
                # an honest per-chunk wall time needs the async dispatch
                # drained; numerics are untouched
                jax.block_until_ready(carry)
        chunks.append(rec)
        if tele is not None:
            counters = carry_counters(carry)
            delta = {key: counters[key] - prev_counters.get(key, 0)
                     for key in counters}
            wall = max(sp.wall_s, 1e-9)
            tele.emit("chunk", step=s + k, steps=k,
                      wall_s=round(wall, 6),
                      steps_per_s=round(k / wall, 3),
                      counters=counters, delta=delta)
            prev_counters = counters
        if health is not None:
            now = snapshot(s + k, carry)
            try:
                check_chunk(prev, now, health, n=n, dt_ms=dt_ms)
            except SimulationHealthError as e:
                if tele is not None:
                    value = (float(e.value) if np.isscalar(e.value)
                             else e.value)
                    tele.emit("health", kind=e.kind, step=e.step,
                              value=value, threshold=e.threshold)
                raise
            prev = now
        if checkpointer is not None:
            saved = checkpointer.save(s + k, carry,
                                      concat_records(chunks, time_axis))
            if saved and tele is not None:
                tele.emit("checkpoint", step=s + k,
                          async_save=checkpointer.async_save)
        s += k
    if checkpointer is not None:
        checkpointer.join()
    return carry, concat_records(chunks, time_axis)


# --------------------------------------------------------------------------
# Supervisor
# --------------------------------------------------------------------------

def run_resilient(run_fn: Callable[[Optional[int], Optional[CapacityConfig]],
                                   Any],
                  checkpoint_dir: Optional[str] = None,
                  max_restarts: int = 3,
                  capacity: Optional[CapacityConfig] = None,
                  escalate=None, max_escalations: int = 4,
                  backoff: Optional[BackoffPolicy] = BackoffPolicy(),
                  sleep: Callable[[float], None] = time.sleep,
                  rng=None):
    """Supervise ``run_fn(resume_step, capacity)`` to completion.

    Generalizes :func:`repro.train.fault.run_with_recovery`: the resume
    signal is the explicit ``latest_step(checkpoint_dir)`` (or None when
    no checkpoint exists yet), never a magic value.  Policy:

    * **crash** (any ``RuntimeError`` that is not a health breach — e.g.
      an injected partition failure from the ``faulty`` exchange scheme):
      restart from the latest checkpoint, up to ``max_restarts`` times;
    * **drop-rate breach** (:class:`SimulationHealthError` with
      ``kind="drop_rate"``): call ``escalate(error, capacity) ->
      CapacityConfig`` (default: double every budget via
      :func:`repro.core.capacity.escalate_capacity`) and resume from the
      last *healthy* checkpoint, up to ``max_escalations`` times —
      converging to a lossless run with drops exactly accounted, because
      the breached chunk was never checkpointed and is re-run under the
      larger budgets;
    * **poison** (``nonfinite`` / ``saturated`` / ``rate_envelope``):
      deterministic corruption — re-raise immediately.

    Every retry waits out ``backoff.delay(attempt)`` first (jittered
    exponential, capped — see :class:`BackoffPolicy`; ``backoff=None``
    restores the immediate-retry behaviour), so a crash-looping run
    never hot-spins the host or re-stampedes in sync with its neighbors.
    ``sleep`` / ``rng`` exist for tests.

    With a telemetry session active, every supervision decision is
    emitted: an ``escalation`` event per capacity escalation, a
    ``restart`` event per crash recovery — each carrying the applied
    ``backoff_s`` (``health`` breach events come from
    :func:`run_chunked` itself).
    """
    from repro.train.checkpoint import latest_step
    from .capacity import escalate_capacity
    if escalate is None:
        escalate = lambda e, cap: escalate_capacity(cap)  # noqa: E731
    tele = obs.active()
    restarts = escalations = 0
    resume: Optional[int] = None

    def _latest():
        return latest_step(checkpoint_dir) if checkpoint_dir else None

    def _wait(attempt: int) -> float:
        if backoff is None:
            return 0.0
        d = backoff.delay(attempt, rng)
        if d > 0:
            sleep(d)
        return round(d, 6)

    with obs.span("run_resilient"):
        while True:
            try:
                return run_fn(resume, capacity)
            except SimulationHealthError as e:
                if e.kind not in RECOVERABLE_KINDS:
                    raise
                escalations += 1
                if escalations > max_escalations:
                    raise
                capacity = escalate(e, capacity)
                if capacity is None:
                    raise   # escalation policy declined — surface the breach
                resume = _latest()
                waited = _wait(escalations)
                if tele is not None:
                    tele.emit("escalation", attempt=escalations,
                              resume_step=resume, kind=e.kind,
                              backoff_s=waited)
            except RuntimeError as e:
                restarts += 1
                if restarts > max_restarts:
                    raise
                resume = _latest()
                waited = _wait(restarts)
                if tele is not None:
                    tele.emit("restart", attempt=restarts,
                              resume_step=resume, error=type(e).__name__,
                              backoff_s=waited)


__all__ = ["BackoffPolicy", "HealthConfig", "HealthSnapshot",
           "RECOVERABLE_KINDS", "SimCheckpointer", "SimulationHealthError",
           "carry_counters", "check_chunk", "concat_records",
           "health_stats_init", "health_step_stats", "lane_snapshots",
           "run_chunked", "run_resilient", "snapshot"]
