"""Capacity-constrained greedy partitioning of neurons to cores (paper §3.2.4).

The paper's scheme: neurons are assigned in ascending index order to the list
of available partitions; a partition tracks three accumulators (neuron count,
incoming-connection units, outgoing-connection units — *effective* counts
under the chosen compression scheme).  If an assignment would exceed any
capacity the neuron goes to the next available partition; a partition whose
remaining capacity is "sufficiently exhausted" is marked full.

We reproduce that exactly (it is what produced the paper's 12-chip SAR /
20-chip SSD layouts) plus the even-split baseline it is compared against,
and report the Figs 8-10 per-core distributions.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .compress import (CoreBudget, WEIGHT_BITS, effective_fan_in_sar)
from .connectome import Connectome


@dataclasses.dataclass(frozen=True)
class Partitioning:
    part_of_neuron: np.ndarray   # [n] int32 partition id (contiguous ranges)
    offsets: np.ndarray          # [P+1] neuron index range per partition
    scheme: str                  # "sar" | "ssd"

    @property
    def n_parts(self) -> int:
        return len(self.offsets) - 1

    def neurons_per_part(self) -> np.ndarray:
        return np.diff(self.offsets)


@dataclasses.dataclass(frozen=True)
class PartitionCaps:
    """Capacities per partition, in 'connection units' of the active scheme."""
    max_neurons: int
    max_in_units: int     # SAR: effective fan-in entries; SSD: capped fan-in
    max_out_units: int    # SAR: axon-program entries (fan-out); SSD: eff fan-out
    exhaust_frac: float = 0.97  # mark-full threshold


def caps_from_budget(budget: CoreBudget, scheme: str,
                     fan_in_cap: int = 4096) -> PartitionCaps:
    usable = int(budget.syn_mem_bytes * (1.0 - budget.spike_buffer_reserve))
    per_entry = budget.bytes_per_syn
    if scheme == "sar":
        return PartitionCaps(
            max_neurons=budget.max_neurons,
            max_in_units=usable // per_entry,
            max_out_units=budget.max_axon_entries,
        )
    elif scheme == "ssd":
        return PartitionCaps(
            max_neurons=budget.max_neurons,
            max_in_units=usable // per_entry,
            max_out_units=budget.max_axon_entries,
        )
    raise ValueError(scheme)


def greedy_partition(
    c: Connectome,
    caps: PartitionCaps,
    scheme: str = "sar",
    fan_in_cap: int = 4096,
    bits: int = WEIGHT_BITS,
    n_parts_hint: int | None = None,
) -> Partitioning:
    """Paper's greedy scheme.  Neuron i carries (1, in_units[i], out_units[i]);
    partitions fill in ascending order.  Returns contiguous neuron ranges
    (STACS repartitioning renumbers neurons by partition order — we keep the
    original order and cut it into ranges, which is identical up to the
    paper's own renumbering)."""
    n = c.n
    if scheme == "sar":
        in_units = effective_fan_in_sar(c, bits)
        out_units = c.fan_out.copy()          # axon program: full fan-out
    elif scheme == "ssd":
        in_units = np.minimum(c.fan_in, fan_in_cap)
        # SSD eff fan-out depends on the partitioning itself; the paper uses
        # an estimate then validates.  We estimate with fan_out capped by a
        # typical partition count (upper bound: distinct targets <= fanout).
        out_units = c.fan_out.copy()
    else:
        raise ValueError(scheme)

    in_units = in_units.astype(np.int64)
    out_units = out_units.astype(np.int64)

    parts_n, parts_in, parts_out = [], [], []
    cur = 0
    acc_n = acc_in = acc_out = 0
    cut_offsets = [0]
    for i in range(n):
        ni, ii, oi = 1, int(in_units[i]), int(out_units[i])
        fits = (acc_n + ni <= caps.max_neurons
                and acc_in + ii <= caps.max_in_units
                and acc_out + oi <= caps.max_out_units)
        if not fits and acc_n > 0:
            parts_n.append(acc_n); parts_in.append(acc_in); parts_out.append(acc_out)
            cut_offsets.append(i)
            cur += 1
            acc_n = acc_in = acc_out = 0
        acc_n += ni; acc_in += ii; acc_out += oi
    parts_n.append(acc_n); parts_in.append(acc_in); parts_out.append(acc_out)
    cut_offsets.append(n)
    offsets = np.asarray(cut_offsets, dtype=np.int64)
    part_of = np.repeat(np.arange(len(offsets) - 1, dtype=np.int32),
                        np.diff(offsets))
    del cur, n_parts_hint
    return Partitioning(part_of_neuron=part_of, offsets=offsets, scheme=scheme)


def even_partition(c: Connectome, n_parts: int) -> Partitioning:
    """Baseline: equal neuron count per partition (what the paper criticizes)."""
    n = c.n
    base = n // n_parts
    rem = n % n_parts
    sizes = np.full(n_parts, base, dtype=np.int64)
    sizes[:rem] += 1
    offsets = np.zeros(n_parts + 1, dtype=np.int64)
    np.cumsum(sizes, out=offsets[1:])
    part_of = np.repeat(np.arange(n_parts, dtype=np.int32), sizes)
    return Partitioning(part_of_neuron=part_of, offsets=offsets, scheme="even")


def pad_to_uniform(p: Partitioning, n_parts: int, n: int) -> Partitioning:
    """Re-cut a partitioning into exactly `n_parts` contiguous ranges by
    merging/splitting greedily — used to map partitions onto a fixed mesh
    axis size (TPU shards must be equal count; we pad with ghost neurons in
    the engine instead, this just fixes the partition count)."""
    if p.n_parts == n_parts:
        return p
    # split the neuron range into n_parts cuts as close as possible to the
    # original cut points while keeping monotonicity
    target = np.linspace(0, n, n_parts + 1)
    cuts = np.searchsorted(p.offsets, target)
    offsets = np.unique(np.clip(p.offsets[np.minimum(cuts, len(p.offsets) - 1)],
                                0, n))
    if len(offsets) != n_parts + 1:
        offsets = np.round(np.linspace(0, n, n_parts + 1)).astype(np.int64)
    part_of = np.repeat(np.arange(n_parts, dtype=np.int32), np.diff(offsets))
    return Partitioning(part_of_neuron=part_of, offsets=offsets, scheme=p.scheme)


def partition_report(c: Connectome, p: Partitioning,
                     budget: CoreBudget, fan_in_cap: int = 4096,
                     bits: int = WEIGHT_BITS) -> dict:
    """Per-core distributions for Figs 8-10: neurons/core, fan-in/out per
    core (raw + effective), memory utilization fraction."""
    from .compress import core_memory_sar, core_memory_ssd

    eff_in = effective_fan_in_sar(c, bits)
    P = p.n_parts
    per = {"neurons": np.diff(p.offsets)}
    sums = {}
    for name, arr in (("fan_in", c.fan_in), ("fan_out", c.fan_out),
                      ("eff_fan_in", eff_in),
                      ("fan_in_capped", np.minimum(c.fan_in, fan_in_cap))):
        s = np.zeros(P, dtype=np.int64)
        np.add.at(s, p.part_of_neuron, arr)
        sums[name] = s
    per.update(sums)
    if p.scheme == "sar":
        mem = [core_memory_sar(np.array([sums["eff_fan_in"][i]]),
                               np.array([sums["fan_out"][i]]), budget)
               for i in range(P)]
    else:
        mem = [core_memory_ssd(np.array([sums["fan_in_capped"][i]]),
                               np.array([sums["fan_out"][i]]), budget)
               for i in range(P)]
    syn_bytes = np.array([m["syn_bytes"] for m in mem])
    per["mem_util"] = syn_bytes / budget.syn_mem_bytes
    per["n_parts"] = P
    return per
