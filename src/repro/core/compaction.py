"""Hierarchical active-set compaction and the shared bounded ragged gather.

The event-driven delivery paths (monolithic ``event`` engine and the
distributed ``event`` comm scheme) both reduce a boolean spike vector to a
fixed-capacity list of active indices and then ragged-gather those indices'
fan-out synapse runs into a bounded slot budget.  This module is the single
home for both primitives.

Why hierarchical compaction
---------------------------
``jnp.where(spikes, size=K)`` is an O(n) inclusive cumsum over the full
vector every step — at n=60k it dominates the sparse-activity step (~2.7 ms
of a ~4.5 ms step on CPU) even when only a handful of neurons spiked.
:func:`two_level_active` instead

1. reduces spikes to a per-block any-spike mask (``block`` = 128 lanes,
   matching the blocked engine's tile granularity) — a vectorized O(n)
   reduce, ~100x cheaper than the O(n) scan;
2. compacts the O(n/128) block ids with a bounded ``where`` over the mask;
3. compacts *within only the gathered active blocks* — a bounded ``where``
   over ``block_capacity * block`` elements.

Per-step compaction cost is O(n/B + B_cap·B) instead of O(n): sublinear in
n once activity (and hence ``block_capacity``) stops growing with it.

Capacity overruns — more active blocks than ``block_capacity``, more active
neurons than ``spike_capacity``, more fan-out synapses than the slot budget
— are never silent: callers combine :func:`active_fanout_total` (the exact
requested-synapse count) with the delivered count to report exact drops.

Slot->owner assignment
----------------------
``owner[s] = #{k : seg_end[k] <= s}`` equals
``searchsorted(seg_end, slot, side="right")`` but is computed by scattering
a unit bump at each segment end and taking an inclusive cumsum over the
budget — O(S_cap + K) sequential-friendly work instead of the
O(S_cap · log K) gather-heavy probe per slot.
"""

from __future__ import annotations

import jax.numpy as jnp

BLOCK = 128   # compaction granularity; matches the blocked engine's tile


def n_blocks(n: int, block: int = BLOCK) -> int:
    """Number of ``block``-sized blocks covering ``n`` lanes (ceil div)."""
    return -(-n // block)


def derived_block_capacity(n: int, spike_capacity: int,
                           block: int = BLOCK) -> int:
    """Default block budget when a config leaves it 0: every active neuron
    could land in its own block, so ``spike_capacity`` blocks always
    suffice (capped at the total block count)."""
    return max(1, min(n_blocks(n, block), spike_capacity))


def two_level_active(spikes: jnp.ndarray, spike_capacity: int,
                     block_capacity: int, block: int = BLOCK) -> jnp.ndarray:
    """Compact spiking indices into ``[spike_capacity]`` int32, ascending,
    with ``fill = n`` marking unused slots.

    Selection under overflow is hierarchical-prefix: the first
    ``block_capacity`` active blocks (by block id), then the first
    ``spike_capacity`` active neurons (by id) within those blocks.  With
    sufficient capacity this equals ``jnp.where(spikes, size=K, fill=n)``
    exactly; under overflow the kept set is still ascending and
    deterministic, so drop accounting stays exact and reproducible.
    """
    n = spikes.shape[0]
    nb = n_blocks(n, block)
    spp = jnp.pad(spikes, (0, nb * block - n)).reshape(nb, block)
    bmask = jnp.any(spp, axis=1)
    bids = jnp.where(bmask, size=block_capacity, fill_value=nb)[0]
    bids = bids.astype(jnp.int32)
    bvalid = bids < nb
    # gather only the active blocks; invalid slots contribute no spikes
    sub = jnp.logical_and(spp[jnp.minimum(bids, nb - 1)], bvalid[:, None])
    loc = jnp.where(sub.reshape(-1), size=spike_capacity,
                    fill_value=block_capacity * block)[0].astype(jnp.int32)
    lvalid = loc < block_capacity * block
    b = jnp.minimum(loc // block, block_capacity - 1)
    gid = bids[b] * block + loc % block
    return jnp.where(lvalid, gid, n).astype(jnp.int32)


def slot_owner(seg_end: jnp.ndarray, syn_budget: int) -> jnp.ndarray:
    """owner[s] = #{k : seg_end[k] <= s} for s in [0, syn_budget) — equal to
    ``searchsorted(seg_end, slot, side="right")`` but computed by scattering
    a unit bump at each segment end and taking an inclusive cumsum:
    O(S_cap + K) instead of O(S_cap · log K)."""
    bump = jnp.zeros(syn_budget + 1, jnp.int32).at[
        jnp.minimum(seg_end, syn_budget)].add(1)
    return jnp.cumsum(bump[:syn_budget])


def ragged_slots(ids: jnp.ndarray, indptr: jnp.ndarray, syn_budget: int, *,
                 invalid_from: int, gather_size: int):
    """Assign the fan-out synapse runs of compacted ``ids`` to a bounded
    flat slot budget.

    ``ids`` is a ``[K]`` compacted index list (from
    :func:`two_level_active` or an all-gather of such lists) where any
    value ``>= invalid_from`` marks an unused slot.  ``indptr`` is the
    ``[invalid_from + 1]`` CSR row-pointer array of the synapse store the
    caller will gather from; ``gather_size`` bounds the produced indices
    (the store's first-axis length).

    Returns ``(syn_ix [S_cap] i32, ok [S_cap] bool, total i32)``: gather
    indices per slot, slot validity, and the total synapse count requested
    by the valid ids (``total - sum(ok)`` synapses were dropped to the
    budget).  Cost: O(S_cap + K), independent of the store size.
    """
    k = ids.shape[0]
    valid = ids < invalid_from
    safe = jnp.minimum(ids, invalid_from - 1)
    starts = jnp.where(valid, indptr[safe], 0)
    lens = jnp.where(valid, indptr[safe + 1] - indptr[safe], 0)
    seg_end = jnp.cumsum(lens)
    total = seg_end[-1]
    owner = slot_owner(seg_end, syn_budget)
    owner_c = jnp.minimum(owner, k - 1)
    prev_end = jnp.where(owner_c > 0, seg_end[owner_c - 1], 0)
    slot = jnp.arange(syn_budget, dtype=jnp.int32)
    syn_ix = jnp.clip(starts[owner_c] + slot - prev_end, 0, gather_size - 1)
    ok = slot < jnp.minimum(total, syn_budget)
    return syn_ix, ok, total


def active_fanout_total(spikes: jnp.ndarray, indptr: jnp.ndarray):
    """Exact number of synapses the spike vector *requests* — the
    drop-accounting ground truth (requested - delivered = dropped), immune
    to what the bounded compaction kept.  One vectorized O(n) multiply-add,
    no scan/scatter."""
    fo = indptr[1:] - indptr[:-1]
    return jnp.sum(jnp.where(spikes, fo, 0))


__all__ = ["BLOCK", "active_fanout_total", "derived_block_capacity",
           "n_blocks", "ragged_slots", "slot_owner", "two_level_active"]
