"""Event scheme: all_gather of fixed-capacity compacted active-neuron lists.

The spike-message analogue (shared axon routing sends one message per
target core per spike; on a TPU mesh the all_gather of K event slots is
the collective-native equivalent).  Comm volume ∝ activity (K ids/step);
delivery cost ∝ events × their local fan-out (bounded by a synapse
budget).  The per-partition compaction and the bounded ragged gather are
the same :mod:`repro.core.compaction` primitives the monolithic event
engine runs, and drops — budget overruns *and* spikes beyond the event
capacity — are counted exactly in synapse units via the prebuilt global
fan-out table (``DistArrays.src_gfo``).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compaction import derived_block_capacity, ragged_slots, two_level_active
from .arrays import build_dist_arrays
from .base import Topology, register_scheme


def gather_active_events(delayed: jax.Array, cap, topo: Topology):
    """Compact this partition's delayed spikes and all_gather the global
    event lists.

    Returns ``(events [P*K] global ids, idx [K] local kept ids)`` — shared
    by the ``event`` and sharded ``blocked`` schemes, whose cross-cut
    exchange is identical (they differ only in local delivery granularity:
    synapse runs vs 128×128 tiles)."""
    U, n_glob = topo.part_size, topo.n_global
    bcap = cap.block_capacity or derived_block_capacity(U, cap.spike_capacity)
    idx = two_level_active(delayed, cap.spike_capacity, bcap)
    my = jax.lax.axis_index(topo.axis)
    gid = jnp.where(idx < U, idx + my * U, n_glob).astype(jnp.int32)
    events = jax.lax.all_gather(gid, topo.axis).reshape(-1)   # [P*K]
    return events, idx


def capacity_overflow_fanout(delayed, idx, src_gfo, U: int):
    """Global fan-out of the spikes the bounded compaction could not keep —
    they never enter any partition's event list, so their whole fan-out is
    dropped (exact: requested minus kept, in synapse units)."""
    req_fo = jnp.sum(jnp.where(delayed, src_gfo, 0))
    kept_fo = jnp.sum(jnp.where(idx < U, src_gfo[jnp.minimum(idx, U - 1)], 0))
    return req_fo - kept_fo


def deliver_events(events: jax.Array, out_indptr, out_tgt, out_w,
                   U: int, n_glob: int, syn_budget: int
                   ) -> tuple[jax.Array, jax.Array]:
    """events: [E] global ids (pad = n_glob).  Bounded ragged gather via the
    shared :func:`repro.core.compaction.ragged_slots` — the same code path
    the monolithic event engine runs, applied to the all-gathered event
    list against this partition's source-major local store."""
    syn_ix, ok, total = ragged_slots(
        events, out_indptr, syn_budget,
        invalid_from=n_glob, gather_size=out_tgt.shape[0])
    contrib = jnp.where(ok, out_w[syn_ix], 0.0)
    tgt = jnp.where(ok, out_tgt[syn_ix], U)
    g = jax.ops.segment_sum(contrib, tgt, num_segments=U + 1)[:U]
    return g, jnp.maximum(total - syn_budget, 0)


@register_scheme
class EventExchange:
    name = "event"

    def build(self, d, sim, cap):
        return build_dist_arrays(d)

    def init_stats(self) -> dict:
        return {}

    def exchange(self, state, delayed, cap, topo: Topology):
        return gather_active_events(delayed, cap, topo)

    def deliver(self, state, payload, delayed, sim, cap, topo: Topology):
        events, idx = payload
        U, n_glob = topo.part_size, topo.n_global
        g, drop = deliver_events(events, state.out_indptr, state.out_tgt,
                                 state.out_w, U, n_glob, cap.syn_budget)
        drop = drop.astype(jnp.int32) + capacity_overflow_fanout(
            delayed, idx, state.src_gfo, U)
        return g, drop, {}
