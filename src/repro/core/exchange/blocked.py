"""Sharded blocked scheme: the Pallas tile store partitioned over the mesh.

The ROADMAP's multi-device ``blocked`` engine, shipped as an exchange
scheme on the unified step core.  Each partition owns the 128×128 weight
tiles whose *targets* are local; tile source-block ids stay **global**
(the per-partition ``blk_id`` remap), indexing one shared spike-bitmap
space.  Per step:

* cross-cut exchange is identical to the ``event`` scheme — compact local
  delayed spikes hierarchically, all_gather the K-slot global id lists
  (comm volume ∝ activity, never the full bitmap);
* each partition scatters the gathered events back into a global spike
  bitmap, blocks it, and runs the :mod:`repro.kernels.spike_prop` Pallas
  kernel against its local tile store — every tile whose global source
  block is spike-silent this step is skipped (``pl.when`` gating; on TPU
  the grid-level DMA skip also saves the HBM→VMEM weight stream).

Cost ∝ live local tiles + K·P exchanged ids: tile-granular skip inside
each partition plus event exchange across the cut.  Delivery itself is
exact (dense tiles, no synapse budget); the only drops are spikes beyond
the event capacity, counted in exact synapse units like the event scheme.
Per-step gating effectiveness is observable: the scheme accumulates
``tiles_live`` / ``tiles_skipped`` counters into ``DistResult.stats``.

Fused path: with ``sim.engine = "blocked_fused"`` the scheme reports the
``fuses_lif`` capability and the per-partition delivery kernel also runs
the LIF update (float32 or Q19.12 int32) before emitting the local spike
vector — delivered currents and the tile-skip mask never leave VMEM
(:func:`repro.kernels.spike_prop.kernel.fused_deliver_lif_pallas`); the
cross-cut event exchange and the drop accounting are unchanged and the
result is bit-identical to the unfused blocked scheme.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..engines.base import register_state, static_field
from .arrays import build_src_gfo
from .base import Topology, memoized_build, register_scheme
from .event import capacity_overflow_fanout, gather_active_events


@register_state
@dataclasses.dataclass(frozen=True)
class ShardedBlockedState:
    blk_id: jax.Array        # [P, n_tb, E] i32 global source-block per tile
    weights: jax.Array       # [P, n_tb, E, TGT_BLK, SRC_BLK] f32
    src_gfo: jax.Array       # [P, U] i32 global fan-out of local sources
    n_sb: int = static_field(default=0)       # global source blocks
    tiles_stored: int = static_field(default=0)   # total over partitions
    occupancy: float = static_field(default=0.0)
    interpret: bool = static_field(default=True)


@register_scheme
class BlockedExchange:
    name = "blocked"

    def build(self, d, sim, cap) -> ShardedBlockedState:
        # memoize the device-resident state (not just the host grouping) so
        # repeated runs on one snapshot skip the tile-store upload too,
        # matching build_dist_arrays
        def build_state():
            from repro.kernels.spike_prop.ops import build_blocked_sharded
            bs = build_blocked_sharded(d)
            return ShardedBlockedState(
                blk_id=jnp.asarray(bs.blk_id),
                weights=jnp.asarray(bs.weights),
                src_gfo=build_src_gfo(d), n_sb=bs.n_sb,
                tiles_stored=bs.tiles_stored, occupancy=bs.occupancy,
                interpret=jax.default_backend() != "tpu")
        return memoized_build(d, "blocked_state", build_state)

    def init_stats(self) -> dict:
        return {"tiles_live": jnp.int32(0), "tiles_skipped": jnp.int32(0)}

    def exchange(self, state, delayed, cap, topo: Topology):
        return gather_active_events(delayed, cap, topo)

    @staticmethod
    def _event_spike_blocks(state, events, n_glob):
        """Gathered events -> the blocked global spike bitmap: [n_sb,
        SRC_BLK] blocks plus the kernel operand with its trailing zero pad
        block (ids are disjoint across partitions; pad slots land in a
        scratch lane)."""
        from repro.kernels.spike_prop.kernel import SRC_BLK
        npad = state.n_sb * SRC_BLK
        valid = events < n_glob
        spk = jnp.zeros(npad + 1, jnp.float32).at[
            jnp.where(valid, events, npad)].set(1.0)[:npad]
        blocks = spk.reshape(state.n_sb, SRC_BLK)
        spk_pad = jnp.concatenate(
            [blocks, jnp.zeros((1, SRC_BLK), jnp.float32)])
        return blocks, spk_pad

    @staticmethod
    def _tile_stats(state, bmask):
        """Live/skipped stored-tile counters from the [n_sb] block-live
        mask — observability only; the kernels gate on their own copy of
        the mask (the unfused one on the nspk operand, the fused one on a
        reduce that never leaves VMEM)."""
        bmask_pad = jnp.concatenate([bmask, jnp.zeros((1,), bool)])
        stored = state.blk_id < state.n_sb
        live = jnp.sum(jnp.logical_and(stored, bmask_pad[state.blk_id]))
        skipped = jnp.sum(stored) - live
        return {"tiles_live": live.astype(jnp.int32),
                "tiles_skipped": skipped.astype(jnp.int32)}

    def deliver(self, state, payload, delayed, sim, cap, topo: Topology):
        from repro.kernels.spike_prop.kernel import spike_deliver_pallas
        events, idx = payload
        U, n_glob = topo.part_size, topo.n_global

        blocks, spk_pad = self._event_spike_blocks(state, events, n_glob)
        nspk = spk_pad.sum(axis=1).astype(jnp.int32)
        out = spike_deliver_pallas(state.blk_id, state.weights, spk_pad, nspk,
                                   interpret=state.interpret)
        g = out.reshape(-1)[:U]

        drop = capacity_overflow_fanout(delayed, idx, state.src_gfo, U)
        return g, drop, self._tile_stats(state, nspk[:-1] > 0)

    # -- fused-integration capability (engine="blocked_fused"): the same
    #    event exchange + tile store, but the local delivery kernel also
    #    integrates — currents and the tile-skip mask stay in VMEM --

    def fuses_lif(self, sim) -> bool:
        from ..engines import engine_integrates_lif
        return engine_integrates_lif(sim.engine)

    def deliver_fused(self, state, payload, delayed, lif, drive, sim, cap,
                      topo: Topology):
        from repro.kernels.spike_prop.ops import fused_step
        events, idx = payload
        U, n_glob = topo.part_size, topo.n_global

        blocks, spk_pad = self._event_spike_blocks(state, events, n_glob)
        new_lif, spikes = fused_step(
            state.blk_id, state.weights, spk_pad, lif, drive, U,
            sim.params, sim.fixed_point, state.interpret)
        drop = capacity_overflow_fanout(delayed, idx, state.src_gfo, U)
        return new_lif, spikes, drop, self._tile_stats(
            state, jnp.any(blocks != 0, axis=1))
