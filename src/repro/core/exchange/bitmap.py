"""Bitmap scheme: all_gather of the per-partition spike bitmap.

One aggregated message per core pair — the paper's shared-synaptic-delivery
analogue.  Comm volume is fixed (P*U bits/step) regardless of activity;
delivery cost ∝ local nnz (a target-major gather + segment_sum against the
partition's in-CSR with global source ids).  Exact: nothing is ever
dropped.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .arrays import build_dist_arrays
from .base import Topology, register_scheme


def deliver_bitmap(spk_global: jax.Array, arr_src, arr_tgt, arr_w, U: int
                   ) -> jax.Array:
    """spk_global: [P*U] bool; local in-CSR gather + segment_sum -> [U]."""
    spk_pad = jnp.concatenate([spk_global.astype(jnp.float32),
                               jnp.zeros((1,), jnp.float32)])
    contrib = arr_w * spk_pad[arr_src]
    return jax.ops.segment_sum(contrib, arr_tgt, num_segments=U + 1)[:U]


@register_scheme
class BitmapExchange:
    name = "bitmap"

    def build(self, d, sim, cap):
        return build_dist_arrays(d)

    def init_stats(self) -> dict:
        return {}

    def exchange(self, state, delayed, cap, topo: Topology):
        return jax.lax.all_gather(delayed, topo.axis).reshape(topo.n_global)

    def deliver(self, state, spk_all, delayed, sim, cap, topo: Topology):
        g = deliver_bitmap(spk_all, state.syn_src, state.syn_tgt, state.syn_w,
                           topo.part_size)
        return g, jnp.int32(0), {}
