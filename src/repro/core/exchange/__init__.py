"""Exchange-scheme registry: one module per partition-communication strategy.

Importing this package registers every built-in scheme:

======== ==================================================================
local    degenerate P=1 scheme (no collectives) — delegates to the
         delivery-engine registry; the monolithic ``simulate()`` path
bitmap   all_gather of the per-partition spike bitmap (fixed comm volume,
         delivery ∝ local nnz) — the shared-synaptic-delivery analogue
event    all_gather of K-slot compacted active-id lists (comm ∝ activity,
         delivery bounded by the synapse budget) — the spike-message
         analogue, on the shared :mod:`repro.core.compaction` primitives
blocked  sharded Pallas tile store: event exchange across the cut,
         tile-granular skip inside each partition (per-partition blk_id
         remap into the global spike-block space); with
         ``sim.engine="blocked_fused"`` the local kernel also integrates
         (fused delivery->LIF, currents never leave VMEM)
faulty   fault-injection wrapper around any of the above: dropped/corrupt
         payloads at configured steps, host-side partition failures and
         stragglers — the resilience layer's CI test double
======== ==================================================================

See ``docs/distributed.md`` for the comparison and
:mod:`repro.core.exchange.base` for the :class:`ExchangeScheme` protocol.
"""

from .base import (ExchangeScheme, Topology, available_schemes, get_scheme,
                   memoized_build, register_scheme)
from .arrays import DistArrays, build_dist_arrays
from . import bitmap, blocked, event, faulty, local   # noqa: F401 (register)
from .bitmap import BitmapExchange
from .blocked import BlockedExchange, ShardedBlockedState
from .event import EventExchange, gather_active_events
from .faulty import ExchangeFault, FaultSpec, FaultyExchange, configure_faulty
from .local import LocalExchange

__all__ = [
    "ExchangeScheme", "Topology", "available_schemes", "get_scheme",
    "memoized_build", "register_scheme",
    "DistArrays", "build_dist_arrays",
    "BitmapExchange", "BlockedExchange", "EventExchange", "LocalExchange",
    "ShardedBlockedState", "gather_active_events",
    "ExchangeFault", "FaultSpec", "FaultyExchange", "configure_faulty",
]
