"""Local scheme: the degenerate P=1 exchange (no collectives).

The monolithic simulation loop is the single-partition case of the
paper's model of computation: every neuron lives on one "core", so the
spike exchange is the identity and delivery is whatever the registered
delivery engine (:mod:`repro.core.engines`, ``SimConfig.engine``) does.
Routing ``simulate()`` through this scheme is what lets the monolithic
and distributed entry points share one step body verbatim
(:mod:`repro.core.step`).
"""

from __future__ import annotations

from .base import Topology, register_scheme


@register_scheme
class LocalExchange:
    name = "local"

    def build(self, c, sim, cap):
        from ..engines import get_engine
        return get_engine(sim.engine).build(c, sim)

    def init_stats(self) -> dict:
        return {}

    def exchange(self, state, delayed, cap, topo: Topology):
        return delayed

    def deliver(self, state, payload, delayed, sim, cap, topo: Topology):
        from ..engines import get_engine
        g, drop = get_engine(sim.engine).deliver(state, payload, sim)
        return g, drop, {}

    # -- fused-integration capability: delegated to the engine registry --

    def fuses_lif(self, sim) -> bool:
        from ..engines import engine_integrates_lif
        return engine_integrates_lif(sim.engine)

    def deliver_fused(self, state, payload, delayed, lif, drive, sim, cap,
                      topo: Topology):
        from ..engines import get_engine
        new_lif, spikes, drop = get_engine(sim.engine).deliver_fused(
            state, payload, lif, drive, sim)
        return new_lif, spikes, drop, {}
