"""Fault-injection wrapper scheme: break the exchange layer on purpose.

Recovery machinery that is never exercised is decorative.  This scheme
wraps any registered exchange scheme and injects the three distributed
failure modes the resilience layer (:mod:`repro.core.health`) must
survive, without hardware and inside CI:

* **dropped payloads** (``drop_payload_at``): at configured steps the
  chosen partition's delayed spikes are zeroed *before* compaction — its
  whole outgoing fan-out silently vanishes from every partition's event
  list.  Because the inner scheme's drop accounting compares requested
  against kept fan-out, the loss shows up exactly in the ``dropped``
  counter (a lost message is a counted message).
* **corrupt payloads** (``corrupt_payload_at``): the delayed-spike vector
  is rolled by one before compaction — wrong neuron ids enter the event
  list, the downstream signature of a corrupted routing table.
* **partition failure / stragglers** (``fail_at`` / ``straggle_at``):
  host-side, through the chunk driver's ``host_supervise`` hook —
  a configured step inside the upcoming chunk raises
  :class:`ExchangeFault` (once: the retry after recovery proceeds),
  or sleeps ``straggle_s`` seconds per configured straggle step.

Injection is data-driven: the fault step lists ride in the scheme state
as *traced* arrays, so reconfiguring steps never retraces.  The wrapper
delegates ``build`` / ``exchange`` / ``deliver`` to the inner scheme and
adds only the ``exchange_at`` step-aware hook the unified step body
(:mod:`repro.core.step`) consults.  Typical use::

    configure_faulty(inner="event", spec=FaultSpec(partition=1,
                                                   fail_at=(96,)))
    cfg = DistConfig(sim, scheme="faulty")
    run_resilient(lambda resume, cap: simulate_distributed(...), ...)
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .base import Topology, get_scheme, register_scheme


class ExchangeFault(RuntimeError):
    """Injected partition failure (host-side, from ``host_supervise``)."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """What to break, where, and when (step indices are global)."""

    partition: int = 0
    drop_payload_at: tuple = ()      # zero the partition's outgoing spikes
    corrupt_payload_at: tuple = ()   # roll its spike vector by one
    fail_at: tuple = ()              # raise ExchangeFault (host, once each)
    straggle_at: tuple = ()          # sleep straggle_s (host)
    straggle_s: float = 0.05


class FaultyState(NamedTuple):
    """Partition-stacked wrapper state: the inner scheme's state plus the
    fault plan as traced arrays (leaves all carry the leading P axis the
    distributed runners vmap/shard over)."""

    inner: Any
    part: jax.Array        # [P] int32, the faulty partition id (replicated)
    drop_at: jax.Array     # [P, Kd] int32 step ids (empty -> no injection)
    corrupt_at: jax.Array  # [P, Kc] int32 step ids


def _stacked_steps(steps, n_parts: int) -> jnp.ndarray:
    arr = np.asarray(sorted(steps), dtype=np.int32).reshape(1, -1)
    return jnp.asarray(np.broadcast_to(arr, (n_parts, arr.shape[1])))


@register_scheme
class FaultyExchange:
    """``scheme="faulty"``: the configured inner scheme plus injected
    faults.  Configure via :func:`configure_faulty` before building."""

    name = "faulty"

    def __init__(self):
        self._inner = "event"
        self._spec = FaultSpec()
        self._fired: set = set()

    # -- host-side configuration ------------------------------------------
    def configure(self, inner: str = "event",
                  spec: FaultSpec = FaultSpec()) -> "FaultyExchange":
        if inner in ("faulty", "local"):
            raise ValueError(f"cannot wrap the {inner!r} scheme")
        self._inner = inner
        self._spec = spec
        self._fired = set()
        # The inner-scheme choice is trace-time Python state on this
        # singleton: drop any compiled program that may have baked in the
        # previous choice (the fault *steps* are traced data and never
        # need this).
        try:
            from ..distributed import _run_emulated, _shard_map_fn
            _run_emulated.clear_cache()
            _shard_map_fn.cache_clear()
        except Exception:
            pass
        return self

    @property
    def scheme(self):
        return get_scheme(self._inner)

    # -- ExchangeScheme protocol ------------------------------------------
    def build(self, d, sim, cap) -> FaultyState:
        P_ = d.n_parts
        s = self._spec
        return FaultyState(
            inner=self.scheme.build(d, sim, cap),
            part=jnp.full((P_,), int(s.partition), jnp.int32),
            drop_at=_stacked_steps(s.drop_payload_at, P_),
            corrupt_at=_stacked_steps(s.corrupt_payload_at, P_))

    def init_stats(self) -> dict:
        return self.scheme.init_stats()

    def exchange(self, state: FaultyState, delayed, cap, topo: Topology):
        # t-free protocol entry (never taken: the step body prefers
        # exchange_at when present) — delegate clean.
        return self.scheme.exchange(state.inner, delayed, cap, topo)

    def exchange_at(self, state: FaultyState, delayed, cap,
                    topo: Topology, t):
        """Step-aware exchange: inject on the configured partition at the
        configured steps, then run the inner exchange on the (possibly
        sabotaged) spike vector."""
        on_me = jax.lax.axis_index(topo.axis) == state.part
        hit = lambda at: jnp.any(at == t) & on_me  # noqa: E731
        d = jnp.where(hit(state.drop_at), jnp.zeros_like(delayed), delayed)
        d = jnp.where(hit(state.corrupt_at), jnp.roll(d, 1), d)
        return self.scheme.exchange(state.inner, d, cap, topo)

    def deliver(self, state: FaultyState, payload, delayed, sim, cap,
                topo: Topology):
        return self.scheme.deliver(state.inner, payload, delayed, sim, cap,
                                   topo)

    # -- chunk-driver hook ------------------------------------------------
    def host_supervise(self, start: int, stop: int) -> None:
        """Called by :func:`repro.core.health.run_chunked` before each
        chunk ``[start, stop)``: sleep per straggle step, then raise for a
        configured failure step — once per step, so the supervisor's
        restarted attempt proceeds past it (a crash, not a poison)."""
        s = self._spec
        for t in s.straggle_at:
            if start <= t < stop:
                time.sleep(s.straggle_s)
        for t in s.fail_at:
            if start <= t < stop and t not in self._fired:
                self._fired.add(t)
                raise ExchangeFault(
                    f"injected failure of partition {s.partition} "
                    f"at step {t}")


def configure_faulty(inner: str = "event",
                     spec: FaultSpec = FaultSpec()) -> FaultyExchange:
    """Configure the registered ``faulty`` singleton and return it."""
    scheme = get_scheme("faulty")
    return scheme.configure(inner=inner, spec=spec)


__all__ = ["ExchangeFault", "FaultSpec", "FaultyExchange", "FaultyState",
           "configure_faulty"]
