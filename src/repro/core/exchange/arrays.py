"""Partition-stacked synaptic arrays shared by the exchange schemes.

:func:`build_dist_arrays` turns a :class:`repro.core.dcsr.DCSR` snapshot
into the device-resident per-partition stores the ``bitmap`` and ``event``
schemes consume (the ``blocked`` scheme reuses only the fan-out table and
pad mask).  The build is fully vectorized — one batched stable argsort +
one flat bincount over all partitions, instead of the per-partition Python
loop that used to dominate distributed setup at P ≥ 8 — and memoized on
the DCSR (:func:`repro.core.exchange.base.memoized_build`), so repeated
``simulate_distributed`` calls on the same snapshot pay it once, exactly
like ``build_synapses``/``syn=`` on the monolithic path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from ..dcsr import DCSR
from .base import memoized_build


class DistArrays(NamedTuple):
    """Stacked per-partition synaptic state.  Leading dim = P (sharded)."""
    # target-major (bitmap scheme): local in-CSR with global source ids
    syn_src: jax.Array        # [P, S] int32 global new id; pad = P*U
    syn_tgt: jax.Array        # [P, S] int32 local target;  pad = U
    syn_w: jax.Array          # [P, S] float32
    # source-major (event scheme): per-partition fan-out of *global* sources
    # into local targets.  out_indptr[p, s] = start of global-source s's local
    # synapse run on partition p.
    out_indptr: jax.Array     # [P, P*U + 1] int32
    out_tgt: jax.Array        # [P, S] int32 local target; pad = U
    out_w: jax.Array          # [P, S] float32
    pad_mask: jax.Array       # [P, U] bool — True for real neurons
    src_gfo: jax.Array        # [P, U] int32 global fan-out of local sources
                              # (sum of their synapse runs over all
                              # partitions) — exact drop accounting for
                              # spikes beyond the event capacity


def _build_dist_arrays(d: DCSR) -> DistArrays:
    P_, U, S = d.n_parts, d.part_size, d.s_max
    n_glob = P_ * U

    # event-scheme regroup, batched over partitions: one stable row-wise
    # argsort by global source id.  Pad slots carry src = P*U (sorts last,
    # preserving the pad convention), tgt = U, w = 0 already.
    order = np.argsort(d.syn_src, axis=1, kind="stable")
    src_s = np.take_along_axis(d.syn_src, order, axis=1)
    out_tgt = np.take_along_axis(d.syn_tgt_local, order, axis=1)
    out_w = np.take_along_axis(d.syn_w, order, axis=1)

    # per-partition source histogram as one flat bincount over offset keys
    valid = src_s < n_glob
    part_of = np.broadcast_to(np.arange(P_, dtype=np.int64)[:, None], src_s.shape)
    flat = part_of[valid] * n_glob + src_s[valid]
    counts = np.bincount(flat, minlength=P_ * n_glob).reshape(P_, n_glob)
    out_indptr = np.zeros((P_, n_glob + 1), dtype=np.int32)
    out_indptr[:, 1:] = np.cumsum(counts, axis=1)

    pad = d.inv_perm.reshape(P_, U) >= 0

    # global fan-out per source neuron = its local synapse-run length summed
    # over every partition's source-major indptr
    gfo = counts.sum(axis=0).astype(np.int32)   # [P*U]

    return DistArrays(
        syn_src=jnp.asarray(d.syn_src),
        syn_tgt=jnp.asarray(d.syn_tgt_local),
        syn_w=jnp.asarray(d.syn_w),
        out_indptr=jnp.asarray(out_indptr),
        out_tgt=jnp.asarray(out_tgt.astype(np.int32)),
        out_w=jnp.asarray(out_w.astype(np.float32)),
        pad_mask=jnp.asarray(pad),
        src_gfo=jnp.asarray(gfo.reshape(P_, U)),
    )


def build_dist_arrays(d: DCSR) -> DistArrays:
    """Memoized on the DCSR instance — P≥8 setup cost is paid once per
    snapshot, not once per ``simulate_distributed`` call."""
    def build():
        with obs.span("build", what="dist_arrays"):
            return _build_dist_arrays(d)
    return memoized_build(d, "dist_arrays", build)


def build_src_gfo(d: DCSR) -> jax.Array:
    """[P, U] global fan-out of local sources, standalone (one flat
    bincount) — for schemes like ``blocked`` that need exact
    capacity-overflow drop accounting without the full bitmap/event
    synapse stores."""
    def build():
        n_glob = d.n_parts * d.part_size
        src = d.syn_src[d.syn_src < n_glob]
        gfo = np.bincount(src, minlength=n_glob).astype(np.int32)
        return jnp.asarray(gfo.reshape(d.n_parts, d.part_size))
    return memoized_build(d, "src_gfo", build)


__all__ = ["DistArrays", "build_dist_arrays", "build_src_gfo"]
