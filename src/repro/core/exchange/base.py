"""Exchange-scheme protocol, registry, and build memoization.

An *exchange scheme* is one strategy for moving spikes between
self-contained partitions and turning them into each partition's local
synaptic drive — the paper's §3.2.2-3.2.3 communication layer, made
pluggable exactly like synaptic delivery (:mod:`repro.core.engines`) and
stimulation (:mod:`repro.exp`).  Each scheme lives in its own module under
:mod:`repro.core.exchange` and registers a singleton at import time:

    @register_scheme
    class EventExchange:
        name = "event"
        def build(self, source, sim, cap) -> state: ...       # host, once
        def exchange(self, state, delayed, cap, topo): ...    # collectives
        def deliver(self, state, payload, delayed, sim, cap, topo): ...
        def init_stats(self) -> dict: ...                     # optional

``build`` turns the partitioned network (a :class:`repro.core.dcsr.DCSR`,
or a plain :class:`Connectome` for the degenerate ``local`` scheme) into
partition-stacked device state.  Per step the unified core
(:mod:`repro.core.step`) calls ``exchange`` — the *only* place collectives
(`all_gather` over ``topo.axis``) may appear — and then ``deliver``, which
maps the exchanged payload onto the local ``[U]`` drive plus an exact
dropped-synapse count and an optional dict of scalar stats counters
(accumulated into the carry; see ``init_stats``).

The monolithic simulation loop is the P=1 degenerate case: the ``local``
scheme's exchange is the identity (no collectives) and its deliver
delegates to the delivery-engine registry — which is what lets
``simulate()`` and ``simulate_distributed()`` share one step body.
"""

from __future__ import annotations

import weakref
from typing import Any, NamedTuple, Protocol, runtime_checkable


class Topology(NamedTuple):
    """Static partition geometry threaded through every scheme call.

    ``axis`` names the mesh/vmap axis collectives run over (``None`` for
    the single-partition ``local`` scheme, which must not communicate).
    """

    n_parts: int          # P
    part_size: int        # U: local neuron slots (n itself when P == 1)
    axis: str | None      # collective axis name

    @property
    def n_global(self) -> int:
        return self.n_parts * self.part_size


@runtime_checkable
class ExchangeScheme(Protocol):
    """One partition-exchange strategy (see module docstring)."""

    name: str

    def build(self, source: Any, sim, cap) -> Any:
        """Partitioned network -> partition-stacked device state (host
        work, runs once; memoize via :func:`memoized_build`)."""
        ...

    def exchange(self, state: Any, delayed, cap, topo: Topology) -> Any:
        """Local delayed spikes [U] -> exchanged payload (collectives)."""
        ...

    def deliver(self, state: Any, payload: Any, delayed, sim, cap,
                topo: Topology):
        """Payload -> (g_units [U] f32, dropped i32, stats dict)."""
        ...

    def init_stats(self) -> dict:
        """Zero-initialized per-run stats counters ({} for most schemes)."""
        return {}

    # Optional fused-integration capability (see repro.core.step): a scheme
    # that can run delivery and the LIF update in one kernel implements
    #
    #     def fuses_lif(self, sim) -> bool: ...
    #     def deliver_fused(self, state, payload, delayed, lif, drive,
    #                       sim, cap, topo) -> (new_lif, spikes [U] bool,
    #                                           dropped i32, stats dict)
    #
    # When ``fuses_lif(sim)`` is True the step body calls ``deliver_fused``
    # INSTEAD OF ``deliver`` + its own LIF update — the flag guarantees
    # integration happens exactly once.  Schemes without the hook are
    # unfused (the default; the step body owns the LIF update).


_REGISTRY: dict[str, ExchangeScheme] = {}


def register_scheme(cls):
    """Class decorator: instantiate and register an exchange scheme."""
    inst = cls()
    if not getattr(inst, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty .name")
    _REGISTRY[inst.name] = inst
    return cls


def get_scheme(name: str) -> ExchangeScheme:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown exchange scheme {name!r}; "
            f"available: {sorted(_REGISTRY)}") from None


def available_schemes() -> list[str]:
    return sorted(_REGISTRY)


# --------------------------------------------------------------------------
# Build memoization
# --------------------------------------------------------------------------

_BUILD_CACHE: dict[tuple[int, str], tuple] = {}


def memoized_build(source: Any, key: str, build_fn):
    """Memoize a host-side build on the identity of ``source``.

    ``build_dcsr`` outputs are immutable snapshots, so per-(source, key)
    results are cached for the source's lifetime — the distributed
    analogue of amortizing ``build_synapses`` via ``syn=``.  Entries are
    evicted when the source is garbage-collected (sources are unhashable
    numpy-holding dataclasses, hence the id + weakref bookkeeping)."""
    k = (id(source), key)
    hit = _BUILD_CACHE.get(k)
    if hit is not None and hit[0]() is source:
        return hit[1]
    out = build_fn()
    try:
        ref = weakref.ref(source, lambda _r, k=k: _BUILD_CACHE.pop(k, None))
    except TypeError:
        return out
    _BUILD_CACHE[k] = (ref, out)
    return out


__all__ = ["ExchangeScheme", "Topology", "available_schemes", "get_scheme",
           "memoized_build", "register_scheme"]
