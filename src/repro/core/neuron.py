"""Two-state current-based LIF neuron dynamics (paper Eq. 1).

Float path (Brian2/STACS oracle) and int32 fixed-point path (the Loihi 2
microcode analogue).  Both are pure-jnp and vectorized over neurons; the
Pallas kernel in :mod:`repro.kernels.lif` fuses the same math and is tested
against these functions.

Model (forward Euler, dt):
    dv/dt = (v0 - v + g) / tau_m        (unless refractory)
    dg/dt = -g / tau_g                  (unless refractory)
    v > v_th  ->  v = v_r, g = 0, refractory for tau_ref

Synaptic inputs are integer weights scaled by ``w_scale`` (0.275 mV) and added
to ``g``.  Poisson inputs (sugar experiment) either add to ``g``
(Loihi approximation) or force ``v`` above threshold (Brian2 semantics) —
the paper's Fig 13 ablation toggles exactly this.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

FX_FRAC_BITS = 12  # Q19.12 fixed point, state in units of w_scale


@dataclasses.dataclass(frozen=True)
class LIFParams:
    tau_m: float = 20.0      # ms
    tau_g: float = 5.0       # ms
    tau_ref: float = 2.2     # ms
    v0: float = 0.0          # mV (resting)
    v_r: float = 0.0         # mV (reset)
    v_th: float = 7.0        # mV (threshold)
    w_scale: float = 0.275   # mV per weight quantum
    dt: float = 0.1          # ms
    delay: float = 1.8       # ms (uniform synaptic delay)

    @property
    def ref_steps(self) -> int:
        return max(1, round(self.tau_ref / self.dt))

    @property
    def delay_steps(self) -> int:
        return max(1, round(self.delay / self.dt))

    # ---- float euler coefficients ----
    @property
    def alpha_m(self) -> float:
        return self.dt / self.tau_m

    @property
    def decay_g(self) -> float:
        return 1.0 - self.dt / self.tau_g

    # ---- fixed point coefficients (state unit = w_scale, frac = 2**12) ----
    # Small coefficients (alpha = dt/tau) quantized at Q12 carry a ~2%
    # relative error (e.g. round(0.005*4096)=20 vs 20.48) that biases the
    # membrane trajectory.  We store them at 16 fractional bits and apply
    # them as ((x >> 2) * c16) >> 14 so the int32 product never overflows
    # — the same narrow-multiplier discipline Loihi microcode uses.
    @property
    def fx_one(self) -> int:
        return 1 << FX_FRAC_BITS

    @property
    def fx_alpha_m16(self) -> int:
        return round(self.alpha_m * (1 << 16))

    @property
    def fx_gdecay16(self) -> int:
        """(1 - decay_g) at 16 bits: decay applied as g -= g*(dt/tau_g)."""
        return round((self.dt / self.tau_g) * (1 << 16))

    @property
    def fx_v_th(self) -> int:
        return round(self.v_th / self.w_scale * self.fx_one)

    @property
    def fx_v_r(self) -> int:
        return round(self.v_r / self.w_scale * self.fx_one)

    @property
    def fx_v0(self) -> int:
        return round(self.v0 / self.w_scale * self.fx_one)


# paper defaults: dt=0.1ms (and a faster dt=1ms variant with tau_ref/delay
# rounded to 2 steps, handled automatically by ref_steps/delay_steps).
FLYWIRE_LIF = LIFParams()
FLYWIRE_LIF_1MS = LIFParams(dt=1.0, tau_ref=2.0, delay=2.0)


class LIFState(NamedTuple):
    v: jax.Array       # [n] float32 mV (or int32 fx)
    g: jax.Array       # [n] float32 mV (or int32 fx)
    refrac: jax.Array  # [n] int32 steps remaining


def init_state(n: int, params: LIFParams, fixed_point: bool = False) -> LIFState:
    if fixed_point:
        return LIFState(
            v=jnp.full((n,), params.fx_v0, jnp.int32),
            g=jnp.zeros((n,), jnp.int32),
            refrac=jnp.zeros((n,), jnp.int32),
        )
    return LIFState(
        v=jnp.full((n,), params.v0, jnp.float32),
        g=jnp.zeros((n,), jnp.float32),
        refrac=jnp.zeros((n,), jnp.int32),
    )


def lif_step(
    state: LIFState,
    g_in: jax.Array,
    params: LIFParams,
    v_in: jax.Array | None = None,
    force_spike: jax.Array | None = None,
) -> tuple[LIFState, jax.Array]:
    """One forward-Euler step, float path.

    Args:
      g_in: [n] synaptic drive in mV (integer weights * w_scale, delayed),
        added to g at step start.
      v_in: optional [n] direct membrane drive in mV (Brian2-style Poisson).
      force_spike: optional [n] bool — probabilistic background spikes
        (scaling study): neuron emits a spike this step regardless of v.

    Returns: (new_state, spikes[bool n])
    """
    p = params
    active = state.refrac <= 0
    g = jnp.where(active, state.g + g_in, state.g)
    v = state.v
    if v_in is not None:
        v = jnp.where(active, v + v_in, v)
    v = jnp.where(active, v + p.alpha_m * (p.v0 - v + g), v)
    g = jnp.where(active, g * p.decay_g, g)
    spikes = jnp.logical_and(active, v > p.v_th)
    if force_spike is not None:
        spikes = jnp.logical_or(spikes, jnp.logical_and(active, force_spike))
    v = jnp.where(spikes, p.v_r, v)
    g = jnp.where(spikes, 0.0, g)
    refrac = jnp.where(
        spikes, p.ref_steps, jnp.maximum(state.refrac - 1, 0)
    ).astype(jnp.int32)
    return LIFState(v=v, g=g, refrac=refrac), spikes


def lif_step_fx(
    state: LIFState,
    g_in_units: jax.Array,
    params: LIFParams,
    v_in_units: jax.Array | None = None,
    force_spike: jax.Array | None = None,
) -> tuple[LIFState, jax.Array]:
    """One step, int32 fixed-point path (Loihi 2 microcode analogue).

    ``g_in_units`` are raw integer weight sums (NOT scaled by w_scale) —
    exactly what the quantized synaptic-delivery engines produce.  Internally
    state is Q19.12 in units of w_scale.
    """
    p = params
    one = p.fx_one
    active = state.refrac <= 0
    g = jnp.where(active, state.g + (g_in_units.astype(jnp.int32) << FX_FRAC_BITS),
                  state.g)
    v = state.v
    if v_in_units is not None:
        v = jnp.where(active, v + (v_in_units.astype(jnp.int32) << FX_FRAC_BITS), v)
    dv = (((p.fx_v0 - v + g) >> 2) * p.fx_alpha_m16) >> 14
    v = jnp.where(active, v + dv, v)
    g = jnp.where(active, g - (((g >> 2) * p.fx_gdecay16) >> 14), g)
    spikes = jnp.logical_and(active, v > p.fx_v_th)
    if force_spike is not None:
        spikes = jnp.logical_or(spikes, jnp.logical_and(active, force_spike))
    v = jnp.where(spikes, p.fx_v_r, v)
    g = jnp.where(spikes, 0, g)
    refrac = jnp.where(
        spikes, p.ref_steps, jnp.maximum(state.refrac - 1, 0)
    ).astype(jnp.int32)
    del one
    return LIFState(v=v, g=g, refrac=refrac), spikes


def poisson_drive(
    key: jax.Array, n: int, rate_hz: float, dt_ms: float, mask: jax.Array | None = None
) -> jax.Array:
    """Bernoulli(rate*dt) spike draw for Poisson inputs / background activity."""
    p = rate_hz * dt_ms * 1e-3
    draws = jax.random.bernoulli(key, p, (n,))
    if mask is not None:
        draws = jnp.logical_and(draws, mask)
    return draws


def fx_to_mv(x: jax.Array, params: LIFParams) -> jax.Array:
    return x.astype(jnp.float32) / params.fx_one * params.w_scale


def mv_to_fx(x: jax.Array, params: LIFParams) -> jax.Array:
    return jnp.round(x / params.w_scale * params.fx_one).astype(jnp.int32)
