"""The one partition-aware simulation step (paper §3.2.2-3.2.3).

The paper's central claim is that one model of computation — sparse event
exchange between self-contained cores — spans a single Loihi core and 12
chips.  This module is that claim rendered as code: exactly one step body
— ring-buffer delayed-spike readout, spike exchange/delivery, stimulus
step, LIF integration, pad masking, counters, probe collection — shared
verbatim by ``simulate()`` (the degenerate P=1 ``local`` scheme, no
collectives) and ``simulate_distributed()`` (any multi-partition scheme
under vmap emulation or shard_map).  What varies is *only* the registered
:class:`repro.core.exchange.ExchangeScheme` and the
:class:`~repro.core.exchange.base.Topology` it runs over.

One structural exception, negotiated through a capability flag rather
than a second step body: a scheme whose delivery already *integrates*
(the fused delivery->LIF Pallas kernel, ``engine="blocked_fused"``)
reports ``fuses_lif(sim) == True`` and the step calls its
``deliver_fused`` instead of ``deliver`` + ``apply_drive`` — delivery
and integration happen in one kernel and the step body must not
integrate again.  Everything around that call (ring buffer, stimulus,
pad masking, counters, probes) is still the one shared body.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .exchange.base import ExchangeScheme, Topology
from .health import health_step_stats
from .neuron import LIFState


def _scheme_fuses_lif(scheme: ExchangeScheme, sim) -> bool:
    """Trace-time capability check: does this (scheme, config) pair fuse
    the LIF update into delivery?  Schemes without the hook are unfused —
    the step body then owns the one and only LIF update."""
    fuses = getattr(scheme, "fuses_lif", None)
    return bool(fuses(sim)) if fuses is not None else False


class SimCarry(NamedTuple):
    """Per-partition scan carry (leaves are [U]-shaped; U = n when P = 1)."""
    lif: LIFState
    ring: jax.Array        # [D, U] bool delayed-spike ring buffer
    ptr: jax.Array         # scalar int32
    key: jax.Array
    counts: jax.Array      # [U] int32 spike counts
    dropped: jax.Array     # scalar int32 total dropped synapse events
    stim: Any              # stimulus state pytree (() for stateless stimuli)
    stats: dict            # scheme stats counters (scheme.init_stats())


def sim_step(carry: SimCarry, t, *, scheme: ExchangeScheme, state, stim,
             sim, cap, topo: Topology, probes, pad_mask=None,
             voltage_rows=None):
    """One simulation step on one partition — THE step body.

    ``scheme.exchange`` is the only place collectives may appear;
    everything else is partition-local.  ``pad_mask`` ([U] bool, True for
    real neurons) keeps padding slots inert on padded partitions;
    ``voltage_rows`` optionally remaps the probe's voltage ids onto this
    partition's local rows (see :meth:`repro.exp.ProbeSpec.collect`).
    """
    from repro.exp.stimulus import apply_drive, n_split
    p = sim.params
    keys = jax.random.split(carry.key, n_split(stim))
    delayed = carry.ring[carry.ptr]

    # Optional step-aware exchange (the fault-injection wrapper needs the
    # step index to corrupt/drop payloads at configured steps); ordinary
    # schemes keep the t-free protocol method.
    ex_at = getattr(scheme, "exchange_at", None)
    payload = (scheme.exchange(state, delayed, cap, topo) if ex_at is None
               else ex_at(state, delayed, cap, topo, t))
    sstate, drive = stim.step(carry.stim, keys[1:], t, topo.part_size, p)
    if _scheme_fuses_lif(scheme, sim):
        # fused fast path: the engine already integrated (delivery + LIF
        # in one kernel) — running apply_drive here would double-integrate
        lif, spikes, drop, stats = scheme.deliver_fused(
            state, payload, delayed, carry.lif, drive, sim, cap, topo)
    else:
        g_units, drop, stats = scheme.deliver(state, payload, delayed, sim,
                                              cap, topo)
        lif, spikes = apply_drive(carry.lif, g_units, drive, p,
                                  sim.fixed_point)
    if pad_mask is not None:
        spikes = jnp.logical_and(spikes, pad_mask)

    ring = carry.ring.at[carry.ptr].set(spikes)
    ptr = (carry.ptr + 1) % p.delay_steps
    # health sentinels (repro.core.health) accumulate next to the scheme
    # counters; both dicts are keyed disjointly and the carry's stats
    # structure is the static union fixed at init time
    stats = {**stats, **health_step_stats(lif, sim)}
    new = SimCarry(
        lif=lif, ring=ring, ptr=ptr, key=keys[0],
        counts=carry.counts + spikes.astype(jnp.int32),
        dropped=carry.dropped + drop.astype(jnp.int32),
        stim=sstate,
        stats={k: carry.stats[k] + stats[k] for k in carry.stats})
    return new, probes.collect(spikes=spikes, lif=lif, drop=drop, params=p,
                               voltage_rows=voltage_rows)


def scan_steps(scheme: ExchangeScheme, state, carry: SimCarry, stim, sim,
               cap, topo: Topology, probes, t_steps: int, *, t0=None,
               pad_mask=None, voltage_rows=None):
    """Scan ``t_steps`` of :func:`sim_step` — the shared inner loop of every
    entry point (single-run, vmapped trials, emulated and shard_map
    distributed).

    ``t0`` offsets the step indices (a *traced* scalar, so a chunked run
    reuses one compiled K-step program for every chunk — the supervision
    substrate of :mod:`repro.core.health`); the default None keeps the
    historical 0-based program byte-identical."""
    def step(c, t):
        return sim_step(c, t, scheme=scheme, state=state, stim=stim, sim=sim,
                        cap=cap, topo=topo, probes=probes, pad_mask=pad_mask,
                        voltage_rows=voltage_rows)
    ts = jnp.arange(t_steps, dtype=jnp.int32)
    if t0 is not None:
        ts = ts + jnp.asarray(t0, jnp.int32)
    return jax.lax.scan(step, carry, ts)


__all__ = ["SimCarry", "scan_steps", "sim_step"]
