"""Dense engine: g = W @ spikes.

The naive matmul the paper calls "computationally wasteful when the
spiking activity is sparse".  Cost and memory are O(n^2) regardless of
activity — test-scale oracle only.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..connectome import Connectome
from .base import quantized_in_weights, register, register_state, static_field


@register_state
@dataclasses.dataclass(frozen=True)
class DenseState:
    w: jax.Array                      # [n, n] f32, W[target, source]
    n: int = static_field(default=0)


@register
class DenseEngine:
    name = "dense"

    def build(self, c: Connectome, cfg) -> DenseState:
        w = quantized_in_weights(c, cfg)
        dense = np.zeros((c.n, c.n), np.float32)
        tgt = np.repeat(np.arange(c.n), c.fan_in)
        dense[tgt, c.in_indices] = w
        return DenseState(w=jnp.asarray(dense), n=c.n)

    def deliver(self, state: DenseState, spikes: jax.Array, cfg):
        return state.w @ spikes.astype(jnp.float32), jnp.int32(0)
