"""Event engine: active-set event-driven delivery (the Loihi-like path).

Compacts spiking neurons into a fixed-capacity index list, ragged-gathers
their fan-out synapse ranges into a bounded synapse budget, and
scatter-adds into targets.  Cost ∝ activity — the paper's "performance
advantages increase with sparser activity" path.  Capacity overruns are
*counted* (``dropped``), never silent.

The slot->owner assignment (which active neuron does flat slot ``s``
deliver for?) is the hot part.  It equals
``searchsorted(seg_end, slot, side="right")`` but is computed here by
scattering a unit bump at each segment end and taking an inclusive cumsum
over the budget — O(S_cap + K) sequential-friendly work instead of the
O(S_cap · log K) gather-heavy probe per slot.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..compress import quantize_weights
from ..connectome import Connectome
from .base import register, register_state, static_field


@register_state
@dataclasses.dataclass(frozen=True)
class EventState:
    out_indptr: jax.Array             # [n+1] i32 fan-out row pointers
    out_tgt: jax.Array                # [nnz] i32
    out_w: jax.Array                  # [nnz] f32
    n: int = static_field(default=0)


def auto_capacity(c: Connectome, rate_hz: float, dt_ms: float = 0.1,
                  margin: float = 4.0) -> tuple[int, int]:
    """Provision (spike_capacity, syn_budget) for an expected activity level
    — the static-shape analogue of Loihi's 'work ~ actual spike count'.
    The engine still *counts* drops, so under-provisioning is observable."""
    exp_spikes = max(1.0, c.n * rate_hz * dt_ms * 1e-3)
    cap = int(max(64, min(c.n, margin * exp_spikes)))
    mean_fo = max(1.0, c.nnz / c.n)
    budget = int(max(4096, cap * mean_fo * margin))
    return cap, budget


def slot_owner(seg_end: jax.Array, syn_budget: int) -> jax.Array:
    """owner[s] = #{k : seg_end[k] <= s} for s in [0, syn_budget) — equal to
    ``searchsorted(seg_end, slot, side="right")`` but computed by scattering
    a unit bump at each segment end and taking an inclusive cumsum:
    O(S_cap + K) instead of O(S_cap · log K).  Shared with the distributed
    simulator's bounded ragged gather."""
    bump = jnp.zeros(syn_budget + 1, jnp.int32).at[
        jnp.minimum(seg_end, syn_budget)].add(1)
    return jnp.cumsum(bump[:syn_budget])


@register
class EventEngine:
    name = "event"

    def build(self, c: Connectome, cfg) -> EventState:
        ow = c.out_weights
        if cfg.quantize_bits is not None:
            ow = quantize_weights(ow, cfg.quantize_bits)
        return EventState(
            out_indptr=jnp.asarray(c.out_indptr.astype(np.int32)),
            out_tgt=jnp.asarray(c.out_indices),
            out_w=jnp.asarray(ow.astype(np.float32)), n=c.n)

    def deliver(self, state: EventState, spikes: jax.Array, cfg):
        n = state.n
        capacity, syn_budget = cfg.spike_capacity, cfg.syn_budget
        (act_idx,) = jnp.where(spikes, size=capacity, fill_value=n)
        ai = jnp.minimum(act_idx, n - 1)
        valid_neuron = act_idx < n
        starts = jnp.where(valid_neuron, state.out_indptr[ai], 0)
        fo = jnp.where(valid_neuron,
                       state.out_indptr[ai + 1] - state.out_indptr[ai], 0)
        seg_end = jnp.cumsum(fo)
        total = seg_end[-1]
        owner = slot_owner(seg_end, syn_budget)
        slot = jnp.arange(syn_budget, dtype=jnp.int32)
        owner_c = jnp.minimum(owner, capacity - 1)
        prev_end = jnp.where(owner_c > 0, seg_end[owner_c - 1], 0)
        within = slot - prev_end
        syn_ix = jnp.clip(starts[owner_c] + within, 0,
                          state.out_tgt.shape[0] - 1)
        valid = slot < jnp.minimum(total, syn_budget)
        contrib = jnp.where(valid, state.out_w[syn_ix], 0.0)
        tgt = jnp.where(valid, state.out_tgt[syn_ix], n)
        g = jax.ops.segment_sum(contrib, tgt, num_segments=n + 1)[:n]
        dropped = jnp.maximum(total - syn_budget, 0)
        return g, dropped
