"""Event engine: active-set event-driven delivery (the Loihi-like path).

Compacts spiking neurons into a fixed-capacity index list via the
block-hierarchical compaction in :mod:`repro.core.compaction`, ragged-gathers
their fan-out synapse ranges into a bounded synapse budget, and scatter-adds
into targets.  Cost ∝ activity — the paper's "performance advantages
increase with sparser activity" path.

Per-step cost is O(n/B + B_cap·B + S_cap) where B = 128 (the compaction
block), B_cap = ``block_capacity`` and S_cap = ``syn_budget`` — the only
O(n) work left is vectorized elementwise (the block any-reduce and the
drop-accounting fan-out dot), not the O(n) compaction scan the flat
``jnp.where(spikes, size=K)`` used to pay regardless of activity.

Capacity overruns are *counted* (``dropped``), never silent — including
spikes beyond ``spike_capacity``/``block_capacity``, whose whole fan-out is
reported as dropped synapses (exact: requested − delivered, with the
requested total computed from the full spike vector).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..capacity import CapacityConfig
from ..compaction import (BLOCK, active_fanout_total, derived_block_capacity,
                          n_blocks, ragged_slots, slot_owner,
                          two_level_active)
from ..compress import quantize_weights
from ..connectome import Connectome
from .base import register, register_state, static_field

__all__ = ["Capacity", "EventEngine", "EventState", "auto_capacity",
           "slot_owner"]   # slot_owner re-exported from core.compaction


@register_state
@dataclasses.dataclass(frozen=True)
class EventState:
    out_indptr: jax.Array             # [n+1] i32 fan-out row pointers
    out_tgt: jax.Array                # [nnz] i32
    out_w: jax.Array                  # [nnz] f32
    n: int = static_field(default=0)


#: Joint static-shape provisioning now lives in
#: :class:`repro.core.capacity.CapacityConfig`; ``Capacity`` remains as the
#: historical alias (``auto_capacity`` returns it, ``as_config_kwargs``
#: routes through the ``capacity=`` config field).
Capacity = CapacityConfig


def auto_capacity(c: Connectome, rate_hz: float, dt_ms: float = 0.1,
                  margin: float = 4.0, fanout: str = "p99.9",
                  block: int = BLOCK) -> CapacityConfig:
    """Provision the event path's static budgets for an expected activity
    level — the static-shape analogue of Loihi's 'work ~ actual spike
    count'.  The engine still *counts* drops, so under-provisioning is
    observable.

    The three budgets are derived jointly from one provisioned spike level
    ``Kp = margin × expected spikes/step``:

    * ``spike_capacity`` = ``Kp`` floored at 64 (quiet networks keep burst
      headroom — the slot list is cheap);
    * ``block_capacity`` = ``Kp`` 128-blocks (spikes can never occupy more
      blocks than their count), floored at 32 and capped at the total
      block count — this bounds the within-block compaction scan;
    * ``syn_budget`` = ``Kp`` mean fan-outs + a ``margin``-scaled
      Poisson-fluctuation term (√Kp·std) + a hub cushion for heavy-tailed
      fan-out.  ``fanout`` picks the cushion: a percentile string
      (``"p99"``, ``"p99.9"``, ...), ``"max"`` (never drop on a single
      hub), or ``"mean"`` for the legacy ``cap·mean·margin`` formula,
      which both under-provisions simultaneous hub spikes *and*
      over-provisions the common case by ~margin² (the margin already in
      ``spike_capacity`` gets multiplied in again).

    The budgets directly price the per-step O(B_cap·128 + S_cap) slot
    work, so tight joint provisioning is itself the perf optimisation;
    drops stay exactly counted, so any residual under-provisioning is
    observable rather than silent.
    """
    exp_spikes = max(1.0, c.n * rate_hz * dt_ms * 1e-3)
    kp = margin * exp_spikes
    cap = int(max(64, min(c.n, kp)))
    fo = np.diff(c.out_indptr)
    if fanout == "mean":
        mean_fo = max(1.0, c.nnz / c.n)
        budget = int(max(4096, cap * mean_fo * margin))
    else:
        if fanout == "max":
            hub = float(fo.max()) if c.nnz else 0.0
        elif fanout.startswith("p"):
            hub = float(np.percentile(fo, float(fanout[1:]))) if c.nnz else 0.0
        else:
            raise ValueError(
                f"unknown fanout statistic {fanout!r} "
                "(want 'mean', 'max', or a percentile like 'p99.9')")
        budget = int(max(4096, kp * fo.mean()
                         + margin * np.sqrt(kp) * fo.std() + hub))
    budget = min(budget, max(4096, int(c.nnz)))
    bcap = max(1, min(n_blocks(c.n, block), max(32, int(np.ceil(kp)))))
    return CapacityConfig(spike_capacity=cap, syn_budget=budget,
                          block_capacity=bcap)


@register
class EventEngine:
    name = "event"

    def build(self, c: Connectome, cfg) -> EventState:
        ow = c.out_weights
        if cfg.quantize_bits is not None:
            ow = quantize_weights(ow, cfg.quantize_bits)
        return EventState(
            out_indptr=jnp.asarray(c.out_indptr.astype(np.int32)),
            out_tgt=jnp.asarray(c.out_indices),
            out_w=jnp.asarray(ow.astype(np.float32)), n=c.n)

    def deliver(self, state: EventState, spikes: jax.Array, cfg):
        n = state.n
        cap = cfg.capacity
        bcap = cap.block_capacity or derived_block_capacity(
            n, cap.spike_capacity)
        act_idx = two_level_active(spikes, cap.spike_capacity, bcap)
        syn_ix, ok, total = ragged_slots(
            act_idx, state.out_indptr, cap.syn_budget,
            invalid_from=n, gather_size=state.out_tgt.shape[0])
        contrib = jnp.where(ok, state.out_w[syn_ix], 0.0)
        tgt = jnp.where(ok, state.out_tgt[syn_ix], n)
        g = jax.ops.segment_sum(contrib, tgt, num_segments=n + 1)[:n]
        requested = active_fanout_total(spikes, state.out_indptr)
        delivered = jnp.minimum(total, cap.syn_budget)
        return g, requested - delivered
