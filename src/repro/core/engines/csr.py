"""CSR engine: flat segment-sum over all synapses.

Cost ∝ nnz, independent of activity — the Brian2-like conventional
baseline of the paper's Table 1, and the exactness reference for every
other engine.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..connectome import Connectome
from .base import quantized_in_weights, register, register_state, static_field


@register_state
@dataclasses.dataclass(frozen=True)
class CsrState:
    src: jax.Array                    # [nnz] i32 source per synapse
    tgt: jax.Array                    # [nnz] i32 target per synapse
    w: jax.Array                      # [nnz] f32
    n: int = static_field(default=0)


@register
class CsrEngine:
    name = "csr"

    def build(self, c: Connectome, cfg) -> CsrState:
        w = quantized_in_weights(c, cfg)
        tgt = np.repeat(np.arange(c.n, dtype=np.int32), c.fan_in)
        return CsrState(
            src=jnp.asarray(c.in_indices), tgt=jnp.asarray(tgt),
            w=jnp.asarray(w.astype(np.float32)), n=c.n)

    def deliver(self, state: CsrState, spikes: jax.Array, cfg):
        contrib = state.w * spikes[state.src].astype(jnp.float32)
        g = jax.ops.segment_sum(contrib, state.tgt, num_segments=state.n)
        return g, jnp.int32(0)
