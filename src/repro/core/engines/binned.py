"""Binned engine: SAR bin-compressed delivery.

Per-bin active-source histogram (segment_sum over synapse->bin membership)
followed by a tiny dense dot with each target's unique quantized weights —
the memory-compressed analogue of the paper's shared axon routing.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..compress import BinnedFormat, build_binned
from ..connectome import Connectome
from .base import register, register_state, static_field


@register_state
@dataclasses.dataclass(frozen=True)
class BinnedState:
    src: jax.Array                    # [nnz] i32
    bin_id: jax.Array                 # [nnz] i32 global bin id
    bin_w: jax.Array                  # [n, n_bins] f32
    n: int = static_field(default=0)
    n_bins: int = static_field(default=0)


@register
class BinnedEngine:
    name = "binned"

    def build(self, c: Connectome, cfg) -> BinnedState:
        bf: BinnedFormat = build_binned(
            c, bits=cfg.quantize_bits if cfg.quantize_bits else 16)
        return BinnedState(
            src=jnp.asarray(bf.src), bin_id=jnp.asarray(bf.bin_id),
            bin_w=jnp.asarray(bf.bin_weight.astype(np.float32)),
            n=c.n, n_bins=bf.n_bins)

    def deliver(self, state: BinnedState, spikes: jax.Array, cfg):
        counts = jax.ops.segment_sum(
            spikes[state.src].astype(jnp.float32), state.bin_id,
            num_segments=state.n * state.n_bins)
        counts = counts.reshape(state.n, state.n_bins)
        return (state.bin_w * counts).sum(axis=-1), jnp.int32(0)
