"""Blocked-fused engine: delivery AND LIF integration in one Pallas kernel.

Same 128×128 tile store as the :mod:`blocked <repro.core.engines.blocked>`
engine, but the per-step kernel runs the whole
spike→gather→accumulate→integrate→threshold pipeline per target-row block
without the delivered current ever leaving VMEM — the TPU rendering of the
paper's core locality claim (on Loihi 2 spike delivery and neuron update
share one per-core memory, with no dense-memory-hierarchy round-trip).
The block-level tile-skip mask (``repro.core.compaction``'s first-level
any-spike reduce) is likewise derived inside the kernel from the
VMEM-resident spike block.

This is the first engine with the ``integrates_lif`` capability: the
shared step body (:mod:`repro.core.step`) sees the flag through the
``local`` exchange scheme and calls :meth:`deliver_fused` *instead of*
``deliver`` + ``apply_drive``, so the LIF update runs exactly once.  Both
precisions are bit-identical to the unfused blocked + ``lif_step`` /
``lif_step_fx`` composition (pinned in tests/test_fused.py); the int32
Q19.12 path is the Loihi-faithful one.  ``deliver`` is inherited unfused
for generic parity tooling — the step body never calls it for this
engine.
"""

from __future__ import annotations

from .base import register
from .blocked import BlockedEngine, BlockedState


@register
class BlockedFusedEngine(BlockedEngine):
    name = "blocked_fused"
    integrates_lif = True        # step body must skip its own lif_update

    def deliver_fused(self, state: BlockedState, spikes, lif, drive, cfg):
        """spikes [n] bool, lif LIFState, drive StimDrive ->
        (new_lif, spikes [n] bool, dropped i32)."""
        import jax.numpy as jnp

        from repro.kernels.spike_prop.ops import fused_step, spike_blocks
        spk_pad = spike_blocks(spikes, state.n, state.n_sb)
        new_lif, out = fused_step(
            state.blk_id, state.weights, spk_pad, lif, drive, state.n,
            cfg.params, cfg.fixed_point, state.interpret)
        return new_lif, out, jnp.int32(0)
