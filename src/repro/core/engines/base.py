"""Delivery-engine protocol, registry, and pytree-state plumbing.

A *delivery engine* is one strategy for turning the delayed spike vector
into the per-neuron synaptic drive ``g`` (in integer weight units).  Each
engine lives in its own module under :mod:`repro.core.engines` and
registers a singleton instance at import time:

    @register
    class CsrEngine:
        name = "csr"
        def build(self, c, cfg) -> state: ...       # host -> device, once
        def deliver(self, state, spikes, cfg): ...  # per step, traced

``build`` runs once per :func:`repro.core.engine.simulate` call (or once
per benchmark when the caller passes ``syn=``) and returns a device-resident
state object; ``deliver`` is traced into the jitted simulation step and must
be pure jnp / Pallas.  ``deliver`` returns ``(g_units, dropped)`` where
``dropped`` counts synapse events lost to capacity limits (0 for exact
engines).

State objects are frozen dataclasses registered as JAX pytrees via
:func:`register_state`: array fields are pytree children (traced), fields
declared with ``static_field()`` are aux data (hashable, part of the jit
cache key).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

import jax

from ..connectome import Connectome


# --------------------------------------------------------------------------
# Pytree state helper
# --------------------------------------------------------------------------

def static_field(**kw):
    """Dataclass field stored as pytree aux data (shape/mode metadata)."""
    kw.setdefault("metadata", {})
    kw["metadata"] = {**kw["metadata"], "static": True}
    return dataclasses.field(**kw)


def register_state(cls):
    """Register a frozen dataclass as a pytree: arrays are children,
    ``static_field`` entries are hashable aux data (jit cache key)."""
    fields = dataclasses.fields(cls)
    dyn = tuple(f.name for f in fields if not f.metadata.get("static"))
    static = tuple(f.name for f in fields if f.metadata.get("static"))

    def flatten(s):
        return (tuple(getattr(s, f) for f in dyn),
                tuple(getattr(s, f) for f in static))

    def unflatten(aux, children):
        return cls(**dict(zip(dyn, children)), **dict(zip(static, aux)))

    jax.tree_util.register_pytree_node(cls, flatten, unflatten)
    return cls


# --------------------------------------------------------------------------
# Protocol + registry
# --------------------------------------------------------------------------

@runtime_checkable
class DeliveryEngine(Protocol):
    """One synaptic-delivery strategy (see module docstring).

    Capability flag: an engine that sets ``integrates_lif = True`` fuses
    the LIF neuron update into delivery itself and must provide
    ``deliver_fused(state, spikes, lif, drive, cfg) -> (new_lif,
    spikes [n] bool, dropped i32)``.  The shared step body
    (:mod:`repro.core.step`) then calls ``deliver_fused`` *instead of*
    ``deliver`` + the separate LIF update — the flag is what guarantees
    integration happens exactly once per step (never zero, never twice).
    Engines without the attribute are unfused (the default).
    """

    name: str

    def build(self, c: Connectome, cfg) -> Any:
        """Construct device-resident synaptic state (host work, runs once)."""
        ...

    def deliver(self, state: Any, spikes: jax.Array, cfg
                ) -> tuple[jax.Array, jax.Array]:
        """spikes [n] bool -> (g_units [n] f32, dropped scalar i32)."""
        ...


_REGISTRY: dict[str, DeliveryEngine] = {}


def register(cls):
    """Class decorator: instantiate and register a delivery engine."""
    inst = cls()
    if not getattr(inst, "name", None):
        raise ValueError(f"{cls.__name__} must define a non-empty .name")
    _REGISTRY[inst.name] = inst
    return cls

def get_engine(name: str) -> DeliveryEngine:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown engine {name!r}; available: {sorted(_REGISTRY)}"
        ) from None


def available_engines() -> list[str]:
    return sorted(_REGISTRY)


def engine_integrates_lif(name: str) -> bool:
    """True iff ``name``'s engine fuses the LIF update into delivery (the
    ``integrates_lif`` capability) — the one place exchange schemes ask
    whether the step body's separate LIF update must be skipped."""
    return bool(getattr(get_engine(name), "integrates_lif", False))


# --------------------------------------------------------------------------
# Shared build helpers
# --------------------------------------------------------------------------

def quantized_in_weights(c: Connectome, cfg):
    """Target-major weights with the config's optional 9-bit cap applied."""
    from ..compress import quantize_weights
    w = c.in_weights
    if cfg.quantize_bits is not None:
        w = quantize_weights(w, cfg.quantize_bits)
    return w
