"""Blocked engine: block-gated Pallas spike delivery (TPU-native event path).

Wires the :mod:`repro.kernels.spike_prop` blocked-ELL kernel into the
simulation loop as a first-class engine.  Synapses are grouped into dense
(128 x 128) weight tiles stored only for nonempty (target-block,
source-block) pairs; per step the kernel skips every tile whose source
block emitted no spikes, so cost ∝ live tiles — the tile-granular
rendering of "execution cost proportional to spiking activity rather
than synapse count".

The tile store is built on host once per ``build`` (i.e. once per
``simulate()`` call, or once per benchmark when the caller reuses the
state) and lives on device thereafter; the per-step ``deliver`` only
moves the spike vector.  On TPU the kernel runs compiled (scalar-prefetch
DMA gating); elsewhere it falls back to Pallas interpret mode so the
engine stays testable on CPU.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..connectome import Connectome
from .base import quantized_in_weights, register, register_state, static_field


@register_state
@dataclasses.dataclass(frozen=True)
class BlockedState:
    blk_id: jax.Array                 # [n_tb, E] i32 source-block per tile
    weights: jax.Array                # [n_tb, E, TGT_BLK, SRC_BLK] f32
    n: int = static_field(default=0)
    n_sb: int = static_field(default=0)
    interpret: bool = static_field(default=True)
    occupancy: float = static_field(default=0.0)
    tiles_stored: int = static_field(default=0)


@register
class BlockedEngine:
    name = "blocked"

    def build(self, c: Connectome, cfg) -> BlockedState:
        from repro.kernels.spike_prop.ops import build_blocked
        w = quantized_in_weights(c, cfg)
        bs = build_blocked(c, quantized=w if cfg.quantize_bits else None)
        return BlockedState(
            blk_id=jnp.asarray(bs.blk_id), weights=jnp.asarray(bs.weights),
            n=bs.n, n_sb=bs.n_sb,
            interpret=jax.default_backend() != "tpu",
            occupancy=bs.occupancy, tiles_stored=bs.tiles_stored)

    def deliver(self, state: BlockedState, spikes: jax.Array, cfg):
        from repro.kernels.spike_prop.kernel import spike_deliver_pallas
        from repro.kernels.spike_prop.ops import pad_spike_blocks
        spk_pad, nspk = pad_spike_blocks(spikes, state.n, state.n_sb)
        out = spike_deliver_pallas(state.blk_id, state.weights, spk_pad, nspk,
                                   interpret=state.interpret)
        return out.reshape(-1)[:state.n], jnp.int32(0)
