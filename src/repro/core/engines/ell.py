"""ELL engine: target-major padded gather (the SSD-capped format).

Each target row holds up to ``ell_width_cap`` (source, weight) slots; rows
over the cap are uniformly sampled with weight rescale (paper §3.2.4).
Cost ∝ n * width, activity-independent, but regular — the vectorizable
"shared synaptic delivery" analogue.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..compress import EllFormat, build_ell
from ..connectome import Connectome
from .base import register, register_state, static_field


@register_state
@dataclasses.dataclass(frozen=True)
class EllState:
    idx: jax.Array                    # [n, width] i32, pad = n
    w: jax.Array                      # [n, width] f32
    n: int = static_field(default=0)


@register
class EllEngine:
    name = "ell"

    def build(self, c: Connectome, cfg) -> EllState:
        ell: EllFormat = build_ell(c, cfg.ell_width_cap,
                                   quantize_bits=cfg.quantize_bits)
        return EllState(idx=jnp.asarray(ell.idx), w=jnp.asarray(ell.weight),
                        n=c.n)

    def deliver(self, state: EllState, spikes: jax.Array, cfg):
        spk_pad = jnp.concatenate(
            [spikes.astype(jnp.float32), jnp.zeros((1,))])
        return (state.w * spk_pad[state.idx]).sum(axis=-1), jnp.int32(0)
