"""Delivery-engine registry: one module per synaptic-delivery strategy.

Importing this package registers every built-in engine:

======== ==================================================================
dense    naive W @ s matmul (test-scale oracle)
ell      target-major padded gather, SSD fan-in cap (paper §3.2.4)
csr      flat segment-sum over all synapses (conventional baseline)
event    active-set event-driven scatter (Loihi-like, cost ∝ activity)
binned   SAR bin-compressed histogram delivery (paper §3.2.3)
blocked  block-gated Pallas kernel, cost ∝ live 128x128 tiles (TPU-native)
blocked_fused  blocked delivery + LIF integration fused in one kernel:
         delivered currents and the tile-skip mask never leave VMEM
         (``integrates_lif`` capability — the step body skips its own
         LIF update)
======== ==================================================================

See ``docs/engines.md`` for the comparison matrix and
:mod:`repro.core.engines.base` for the :class:`DeliveryEngine` protocol.
"""

from .base import (DeliveryEngine, available_engines, engine_integrates_lif,
                   get_engine, register, register_state, static_field)
from . import (binned, blocked, blocked_fused, csr, dense, ell,  # noqa: F401
               event)
from .binned import BinnedEngine, BinnedState
from .blocked import BlockedEngine, BlockedState
from .blocked_fused import BlockedFusedEngine
from .csr import CsrEngine, CsrState
from .dense import DenseEngine, DenseState
from .ell import EllEngine, EllState
from .event import Capacity, EventEngine, EventState, auto_capacity

__all__ = [
    "DeliveryEngine", "available_engines", "engine_integrates_lif",
    "get_engine", "register", "register_state", "static_field", "Capacity",
    "auto_capacity", "BinnedEngine", "BinnedState", "BlockedEngine",
    "BlockedFusedEngine", "BlockedState", "CsrEngine", "CsrState",
    "DenseEngine", "DenseState", "EllEngine", "EllState", "EventEngine",
    "EventState",
]
