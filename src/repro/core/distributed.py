"""Distributed multi-core SNN simulation via shard_map (paper §3.2.2-3.2.3).

Maps DCSR partitions onto a device mesh axis ("cores"), one partition per
device, and exchanges spikes between partitions each delay window with one of
two communication schemes mirroring the paper's:

* ``bitmap`` — all_gather of the per-partition spike bitmap: one aggregated
  message per core pair, the shared-synaptic-delivery analogue.  Comm volume
  is fixed (P*U bits/step) regardless of activity; delivery cost ∝ local nnz.

* ``event``  — all_gather of fixed-capacity compacted active-neuron index
  lists: the spike-message analogue (shared axon routing sends one message
  per target core per spike; on a TPU mesh the all_gather of K event slots is
  the collective-native equivalent).  Comm volume ∝ activity (K ids/step);
  delivery cost ∝ events × their local fan-out (bounded by a synapse budget).

Every partition is computationally self-contained except for the spike
exchange — exactly the paper's framing of the edge cut as a sparse,
data-dependent halo.

The same step function also runs unsharded under vmap (``emulate=True``) so
semantics are testable on one device; the shard_map path is exercised in
tests via a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .connectome import Connectome
from .engines.event import slot_owner
from .dcsr import DCSR
from .engine import SimConfig
from .neuron import LIFState, init_state, lif_step, lif_step_fx, poisson_drive


# --------------------------------------------------------------------------
# Per-partition device arrays
# --------------------------------------------------------------------------

class DistArrays(NamedTuple):
    """Stacked per-partition synaptic state.  Leading dim = P (sharded)."""
    # target-major (bitmap scheme): local in-CSR with global source ids
    syn_src: jax.Array        # [P, S] int32 global new id; pad = P*U
    syn_tgt: jax.Array        # [P, S] int32 local target;  pad = U
    syn_w: jax.Array          # [P, S] float32
    # source-major (event scheme): per-partition fan-out of *global* sources
    # into local targets.  out_indptr[p, s] = start of global-source s's local
    # synapse run on partition p.
    out_indptr: jax.Array     # [P, P*U + 1] int32
    out_tgt: jax.Array        # [P, S] int32 local target; pad = U
    out_w: jax.Array          # [P, S] float32
    sugar_mask: jax.Array     # [P, U] bool
    pad_mask: jax.Array       # [P, U] bool — True for real neurons


def build_dist_arrays(d: DCSR, sugar_neurons: np.ndarray | None = None
                      ) -> DistArrays:
    P_, U, S = d.n_parts, d.part_size, d.s_max
    n_glob = P_ * U

    # event-scheme regroup: per partition, sort synapses by global source
    out_indptr = np.zeros((P_, n_glob + 1), dtype=np.int32)
    out_tgt = np.full((P_, S), U, dtype=np.int32)
    out_w = np.zeros((P_, S), dtype=np.float32)
    for p in range(P_):
        valid = d.syn_src[p] < n_glob
        src = d.syn_src[p][valid]
        tgt = d.syn_tgt_local[p][valid]
        w = d.syn_w[p][valid]
        order = np.argsort(src, kind="stable")
        src_s, tgt_s, w_s = src[order], tgt[order], w[order]
        m = len(src_s)
        out_tgt[p, :m] = tgt_s
        out_w[p, :m] = w_s
        counts = np.bincount(src_s, minlength=n_glob)
        np.cumsum(counts, out=out_indptr[p, 1:])

    sugar = np.zeros((P_, U), dtype=bool)
    if sugar_neurons is not None:
        new_ids = d.perm[np.asarray(sugar_neurons)]
        sugar[new_ids // U, new_ids % U] = True
    pad = np.zeros((P_, U), dtype=bool)
    real = d.inv_perm.reshape(P_, U) >= 0
    pad[:] = real

    return DistArrays(
        syn_src=jnp.asarray(d.syn_src),
        syn_tgt=jnp.asarray(d.syn_tgt_local),
        syn_w=jnp.asarray(d.syn_w),
        out_indptr=jnp.asarray(out_indptr),
        out_tgt=jnp.asarray(out_tgt),
        out_w=jnp.asarray(out_w),
        sugar_mask=jnp.asarray(sugar),
        pad_mask=jnp.asarray(pad),
    )


# --------------------------------------------------------------------------
# Per-partition delivery
# --------------------------------------------------------------------------

def _deliver_bitmap(spk_global: jax.Array, arr_src, arr_tgt, arr_w, U: int
                    ) -> jax.Array:
    """spk_global: [P*U] bool; local in-CSR gather + segment_sum -> [U]."""
    spk_pad = jnp.concatenate([spk_global.astype(jnp.float32),
                               jnp.zeros((1,), jnp.float32)])
    contrib = arr_w * spk_pad[arr_src]
    return jax.ops.segment_sum(contrib, arr_tgt, num_segments=U + 1)[:U]


def _deliver_events(events: jax.Array, out_indptr, out_tgt, out_w,
                    U: int, n_glob: int, syn_budget: int
                    ) -> tuple[jax.Array, jax.Array]:
    """events: [E] global ids (pad = n_glob).  Bounded ragged gather."""
    E = events.shape[0]
    ev = jnp.minimum(events, n_glob - 1)
    valid_ev = events < n_glob
    starts = jnp.where(valid_ev, out_indptr[ev], 0)
    lens = jnp.where(valid_ev, out_indptr[ev + 1] - out_indptr[ev], 0)
    seg_end = jnp.cumsum(lens)
    total = seg_end[-1]
    slot = jnp.arange(syn_budget, dtype=jnp.int32)
    owner = slot_owner(seg_end, syn_budget)
    owner_c = jnp.minimum(owner, E - 1)
    prev_end = jnp.where(owner_c > 0, seg_end[owner_c - 1], 0)
    within = slot - prev_end
    syn_ix = jnp.clip(starts[owner_c] + within, 0, out_tgt.shape[0] - 1)
    ok = slot < jnp.minimum(total, syn_budget)
    contrib = jnp.where(ok, out_w[syn_ix], 0.0)
    tgt = jnp.where(ok, out_tgt[syn_ix], U)
    g = jax.ops.segment_sum(contrib, tgt, num_segments=U + 1)[:U]
    return g, jnp.maximum(total - syn_budget, 0)


# --------------------------------------------------------------------------
# The per-device step (works under shard_map or vmap)
# --------------------------------------------------------------------------

class DistCarry(NamedTuple):
    lif: LIFState          # leaves [U] per device
    ring: jax.Array        # [D, U] bool
    ptr: jax.Array         # i32 scalar
    key: jax.Array
    counts: jax.Array      # [U] int32
    dropped: jax.Array     # i32 scalar


@dataclasses.dataclass(frozen=True)
class DistConfig:
    sim: SimConfig
    scheme: str = "event"        # "bitmap" | "event"
    spike_capacity: int = 256    # K per partition (event scheme)
    syn_budget: int = 32_768     # per-partition synapse budget per step


def _dist_step(carry: DistCarry, _, *, arrs: DistArrays, cfg: DistConfig,
               P_: int, U: int, axis: str | None):
    """One simulation step on one partition.  `axis` names the mesh axis for
    collectives; None means the caller runs it under vmap with manual
    all-gather emulation (spmd_axis_name)."""
    sc = cfg.sim
    p = sc.params
    key, k_poisson, k_bg = jax.random.split(carry.key, 3)
    delayed = carry.ring[carry.ptr]                      # [U] bool local

    n_glob = P_ * U
    if cfg.scheme == "bitmap":
        spk_all = jax.lax.all_gather(delayed, axis).reshape(n_glob)
        g_units = _deliver_bitmap(spk_all, arrs.syn_src, arrs.syn_tgt,
                                  arrs.syn_w, U)
        drop = jnp.int32(0)
    elif cfg.scheme == "event":
        idx = jnp.where(delayed, size=cfg.spike_capacity, fill_value=U)[0]
        my = jax.lax.axis_index(axis)
        gid = jnp.where(idx < U, idx + my * U, n_glob).astype(jnp.int32)
        events = jax.lax.all_gather(gid, axis).reshape(-1)   # [P*K]
        g_units, drop = _deliver_events(events, arrs.out_indptr, arrs.out_tgt,
                                        arrs.out_w, U, n_glob, cfg.syn_budget)
        # spikes beyond the per-partition event capacity are dropped too
        over_cap = jnp.maximum(
            delayed.sum().astype(jnp.int32) - cfg.spike_capacity, 0)
        drop = drop.astype(jnp.int32) + over_cap
    else:
        raise ValueError(cfg.scheme)

    v_in = None
    force = None
    if sc.poisson_rate_hz > 0:
        draws = poisson_drive(k_poisson, U, sc.poisson_rate_hz, p.dt,
                              arrs.sugar_mask)
        if sc.poisson_to_v:
            v_in = draws.astype(jnp.float32) * (p.v_th * 1.5)
        else:
            g_units = g_units + draws.astype(jnp.float32) * sc.poisson_weight
    if sc.background_rate_hz > 0:
        force = poisson_drive(k_bg, U, sc.background_rate_hz, p.dt,
                              arrs.pad_mask)

    if sc.fixed_point:
        g_in = jnp.round(g_units).astype(jnp.int32)
        v_fx = (None if v_in is None
                else jnp.round(v_in / p.w_scale).astype(jnp.int32))
        lif, spikes = lif_step_fx(carry.lif, g_in, p, v_fx, force)
    else:
        lif, spikes = lif_step(carry.lif, g_units * p.w_scale, p, v_in, force)
    spikes = jnp.logical_and(spikes, arrs.pad_mask)      # pad neurons inert

    ring = carry.ring.at[carry.ptr].set(spikes)
    ptr = (carry.ptr + 1) % p.delay_steps
    new = DistCarry(lif=lif, ring=ring, ptr=ptr, key=key,
                    counts=carry.counts + spikes.astype(jnp.int32),
                    dropped=carry.dropped + drop)
    return new, None


class DistResult(NamedTuple):
    counts: np.ndarray      # [n_orig] spike counts mapped back to orig ids
    dropped: int


def make_core_mesh(n_cores: int, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    if len(devices) < n_cores:
        raise ValueError(f"need {n_cores} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n_cores]), ("cores",))


def simulate_distributed(
    d: DCSR,
    cfg: DistConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seed: int = 0,
    mesh: Mesh | None = None,
    emulate: bool = False,
) -> DistResult:
    """Run the partitioned network.  ``emulate=True`` uses vmap with
    spmd_axis_name on one device (semantics-identical); otherwise shard_map
    over a "cores" mesh axis with one partition per device."""
    P_, U = d.n_parts, d.part_size
    arrs = build_dist_arrays(d, sugar_neurons)
    sc = cfg.sim

    lif0 = init_state(P_ * U, sc.params, sc.fixed_point)
    lif0 = jax.tree.map(lambda x: x.reshape(P_, U), lif0)
    keys = jax.random.split(jax.random.PRNGKey(seed), P_)
    carry0 = DistCarry(
        lif=lif0,
        ring=jnp.zeros((P_, sc.params.delay_steps, U), dtype=bool),
        ptr=jnp.zeros((P_,), jnp.int32),
        key=keys,
        counts=jnp.zeros((P_, U), jnp.int32),
        dropped=jnp.zeros((P_,), jnp.int32),
    )

    axis = "cores"
    step = functools.partial(_dist_step, arrs=None, cfg=cfg, P_=P_, U=U,
                             axis=axis)

    def run_one(carry, arr):
        # scan over time on one device's partition
        def body(c, _):
            return _dist_step(c, None, arrs=arr, cfg=cfg, P_=P_, U=U,
                              axis=axis)
        c, _ = jax.lax.scan(body, carry, None, length=t_steps)
        return c

    if emulate:
        # vmap over the partition dim with a named axis -> collectives work
        out = jax.jit(jax.vmap(run_one, in_axes=0, axis_name=axis))(carry0, arrs)
    else:
        if mesh is None:
            mesh = make_core_mesh(P_)
        spec_carry = jax.tree.map(lambda _: P("cores"), carry0)
        spec_arr = jax.tree.map(lambda _: P("cores"), arrs)

        def sharded(carry, arr):
            carry = jax.tree.map(lambda x: x[0], carry)   # strip local P dim
            arr = jax.tree.map(lambda x: x[0], arr)
            c = run_one(carry, arr)
            return jax.tree.map(lambda x: x[None], c)

        fn = shard_map(sharded, mesh=mesh, in_specs=(spec_carry, spec_arr),
                       out_specs=spec_carry, check_rep=False)
        out = jax.jit(fn)(carry0, arrs)

    counts_pu = np.asarray(out.counts).reshape(P_ * U)
    counts = np.zeros(d.n_orig, dtype=np.int64)
    valid = d.inv_perm >= 0
    counts[d.inv_perm[valid]] = counts_pu[valid]
    del step
    return DistResult(counts=counts, dropped=int(np.asarray(out.dropped).sum()))
