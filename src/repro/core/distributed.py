"""Distributed multi-core SNN simulation via shard_map (paper §3.2.2-3.2.3).

Maps DCSR partitions onto a device mesh axis ("cores"), one partition per
device.  The per-partition step is the SAME function the monolithic
``simulate()`` runs — the unified step core in :mod:`repro.core.step` —
parameterized by a registered exchange scheme
(:mod:`repro.core.exchange`): ``bitmap`` (all_gather of the spike bitmap,
fixed comm volume), ``event`` (all_gather of K-slot compacted active-id
lists, comm ∝ activity), or ``blocked`` (event exchange across the cut +
tile-granular Pallas delivery inside each partition).  Every partition is
computationally self-contained except for ``scheme.exchange`` — exactly
the paper's framing of the edge cut as a sparse, data-dependent halo.

Because the step body is shared, the distributed path has full
observability parity with the monolithic one: :class:`repro.exp.ProbeSpec`
records (raster / voltage / pop-rate / drops) are collected in-scan per
partition and mapped back to original neuron ids through ``inv_perm``
(pad neurons never appear in any record or count), and
:func:`repro.exp.run_dist_trials` vmaps the whole partitioned scan over a
seed batch.

Stimulation flows through the same :mod:`repro.exp` stimulus pytrees as
the monolithic loop via :func:`repro.exp.shard_stimulus` (stateless
stimuli only).

The same step also runs unsharded under vmap (``emulate=True``) so
semantics are testable on one device; the shard_map path is exercised in
tests via a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import obs

from .capacity import DISTRIBUTED_CAPACITY, CapacityConfig, merge_legacy_capacity
from .dcsr import DCSR
from .engine import SimConfig
from .exchange import (DistArrays, Topology, available_schemes,
                       build_dist_arrays, get_scheme)
from .health import (SimCheckpointer, carry_counters, health_stats_init,
                     run_chunked)
from .neuron import LIFState, init_state
from .step import SimCarry, scan_steps

AXIS = "cores"


@dataclasses.dataclass(frozen=True)
class DistConfig:
    sim: SimConfig
    scheme: str = "event"        # see repro.core.exchange / docs/distributed.md
    # Deprecated capacity shims -> capacity (CapacityConfig); explicit
    # writes warn and merge into .capacity, which is the one read path.
    spike_capacity: Optional[int] = None
    syn_budget: Optional[int] = None
    block_capacity: Optional[int] = None
    capacity: Optional[CapacityConfig] = None

    def __post_init__(self):
        cap = merge_legacy_capacity(
            self.capacity, self.spike_capacity, self.syn_budget,
            self.block_capacity, DISTRIBUTED_CAPACITY, "DistConfig")
        object.__setattr__(self, "capacity", cap)
        # consume the shims: dataclasses.replace must never re-apply them
        for f in ("spike_capacity", "syn_budget", "block_capacity"):
            object.__setattr__(self, f, None)


class DistResult(NamedTuple):
    """``SimResult``-shaped distributed result: everything per-neuron is
    mapped back to *original* neuron ids through ``inv_perm``."""
    counts: np.ndarray        # [n_orig] spike counts
    dropped: int
    state: Any                # LIFState, leaves [n_orig]
    raster: np.ndarray | None  # [T, n_orig] (iff the raster probe is on)
    records: dict             # ProbeSpec records, leading axis T
    stats: dict               # scheme counters (e.g. blocked tiles_live)


def make_core_mesh(n_cores: int, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    if len(devices) < n_cores:
        raise ValueError(f"need {n_cores} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n_cores]), (AXIS,))


# --------------------------------------------------------------------------
# Partitioned run plumbing (shared by the single-seed and trial-batch paths)
# --------------------------------------------------------------------------

def _resolve_dist_stimulus(d: DCSR, sc: SimConfig, sugar_neurons, stimulus):
    from repro.exp.stimulus import legacy_stimulus, shard_stimulus
    if stimulus is None:
        if sugar_neurons is not None:
            warnings.warn(
                "sugar_neurons= is deprecated; pass stimulus= instead "
                "(e.g. repro.exp.PoissonDrive(mask=...) or "
                "legacy_stimulus(cfg, n, sugar_idx, masked=True))",
                DeprecationWarning, stacklevel=4)
        stimulus = legacy_stimulus(sc, d.n_orig, sugar_idx=sugar_neurons,
                                   masked=True)
    elif sugar_neurons is not None:
        raise ValueError(
            "pass either sugar_neurons (legacy drive) or stimulus, "
            "not both — an explicit stimulus ignores sugar_neurons")
    return shard_stimulus(stimulus, d)


def _resolve_dist_probes(d: DCSR, sc: SimConfig, probes):
    """Resolve the probe spec and precompute the per-partition voltage-row
    remap: ``rows[p, i]`` is probe id i's local row on partition p (0 when
    not owned — the host keeps only the owning partition's trace)."""
    if probes is None:
        from repro.exp.probes import ProbeSpec
        probes = ProbeSpec(raster=sc.collect_raster)
    P_, U = d.n_parts, d.part_size
    ids = np.asarray(probes.voltage, dtype=np.int64)
    bad = ids[(ids < 0) | (ids >= d.n_orig)]
    if bad.size:
        raise ValueError(f"voltage probe ids {bad.tolist()} out of range "
                         f"for n={d.n_orig}")
    gid = d.perm[ids] if ids.size else ids
    owner, local = gid // U, gid % U
    rows = np.where(owner[None, :] == np.arange(P_)[:, None], local[None, :],
                    0).astype(np.int32)                     # [P, n_probe]
    return probes, jnp.asarray(rows), owner.astype(np.int64)


def _init_dist_carry(d: DCSR, cfg: DistConfig, stim, scheme,
                     keys: np.ndarray) -> SimCarry:
    """Stacked per-partition carry; ``keys`` is [P, 2] (single run) or
    [P, B, 2] (trial batch — every extra leading key axis becomes a batch
    axis on all per-partition leaves)."""
    P_, U = d.n_parts, d.part_size
    sc = cfg.sim
    batch = keys.shape[1:-1]            # () or (B,)
    shp = (P_,) + batch

    def bcast(x, tail):
        return jnp.broadcast_to(x, shp + tail).copy()

    lif0 = init_state(P_ * U, sc.params, sc.fixed_point)
    lif0 = jax.tree.map(
        lambda x: bcast(x.reshape((P_,) + (1,) * len(batch) + (U,))
                        if batch else x.reshape(P_, U), (U,)), lif0)
    stats0 = {k: bcast(v, ())
              for k, v in {**scheme.init_stats(),
                           **health_stats_init(sc)}.items()}
    return SimCarry(
        lif=lif0,
        ring=jnp.zeros(shp + (sc.params.delay_steps, U), dtype=bool),
        ptr=jnp.zeros(shp, jnp.int32),
        key=jnp.asarray(keys),
        counts=jnp.zeros(shp + (U,), jnp.int32),
        dropped=jnp.zeros(shp, jnp.int32),
        stim=stim.init_state(U),
        stats=stats0,
    )


def _partition_run(scheme, cfg: DistConfig, probes, t_steps: int,
                   topo: Topology, trials: bool):
    """The per-partition run: the unified scan, optionally vmapped over a
    leading trial axis of the carry (state/stimulus broadcast).  ``t0``
    is the *traced* step offset (chunked supervision reuses one compiled
    K-step program per chunk — see :mod:`repro.core.health`)."""
    def run_one(carry, state, stim, pad, vrows, t0):
        def go(cy):
            return scan_steps(scheme, state, cy, stim, cfg.sim, cfg.capacity,
                              topo, probes, t_steps, t0=t0, pad_mask=pad,
                              voltage_rows=vrows)
        return jax.vmap(go)(carry) if trials else go(carry)
    return run_one


@functools.partial(jax.jit, static_argnums=(0, 6, 7, 8, 9),
                   donate_argnums=(1,))
def _run_emulated_jit(scheme_name: str, carry, state, stim, pad, vrows,
                      cfg: DistConfig, probes, t_steps: int, trials: bool,
                      t0=None):
    """vmap over the partition dim with a named axis -> collectives work
    on one device (semantics-identical to the shard_map execution)."""
    P_, U = pad.shape
    run_one = _partition_run(get_scheme(scheme_name), cfg, probes, t_steps,
                             Topology(P_, U, axis=AXIS), trials)
    return jax.vmap(run_one, in_axes=(0, 0, 0, 0, 0, None),
                    axis_name=AXIS)(carry, state, stim, pad, vrows, t0)


# Compile-cache instrumentation (repro.obs): per-signature hit/miss
# counters and trace/compile wall with a telemetry session active; the
# plain jit call otherwise.
_run_emulated = obs.InstrumentedJit(_run_emulated_jit,
                                    "distributed.run_emulated",
                                    static_argnums=(0, 6, 7, 8, 9))


@functools.lru_cache(maxsize=64)
def _shard_map_fn(scheme_name: str, cfg: DistConfig, probes, t_steps: int,
                  trials: bool, mesh: Mesh, P_: int, U: int):
    """One jitted shard_map program per static signature — repeat
    ``simulate_distributed(emulate=False)`` calls are cache hits, matching
    the module-level jit of the emulated path."""
    run_one = _partition_run(get_scheme(scheme_name), cfg, probes, t_steps,
                             Topology(P_, U, axis=AXIS), trials)

    def sharded(carry, state, stim, pad, vrows, t0):
        strip = lambda t: jax.tree.map(lambda x: x[0], t)   # local P dim
        out = run_one(strip(carry), strip(state), strip(stim), pad[0],
                      vrows[0], t0)
        return jax.tree.map(lambda x: x[None], out)

    return obs.InstrumentedJit(
        jax.jit(shard_map(
            sharded, mesh=mesh,
            in_specs=(P(AXIS), P(AXIS), P(AXIS), P(AXIS), P(AXIS), P()),
            out_specs=P(AXIS), check_rep=False)),
        f"distributed.shard_map.{scheme_name}")


def _run_shard_map(scheme_name: str, carry, state, stim, pad, vrows,
                   cfg: DistConfig, probes, t_steps: int, trials: bool,
                   mesh: Mesh, t0=None):
    P_, U = pad.shape
    fn = _shard_map_fn(scheme_name, cfg, probes, t_steps, trials, mesh,
                       P_, U)
    if t0 is None:
        t0 = jnp.int32(0)   # replicated scalar: the spec needs a leaf
    return fn(carry, state, stim, pad, vrows, t0)


def _run_partitioned(d: DCSR, cfg: DistConfig, t_steps: int, keys,
                     sugar_neurons, stimulus, probes, mesh, emulate: bool,
                     trials: bool, chunk_steps: Optional[int] = None,
                     checkpoint_dir: Optional[str] = None,
                     resume: bool = False, async_checkpoint: bool = False):
    if cfg.scheme == "local" or cfg.scheme not in available_schemes():
        raise ValueError(
            f"unknown distributed exchange scheme {cfg.scheme!r}; "
            f"available: {sorted(set(available_schemes()) - {'local'})}")
    scheme = get_scheme(cfg.scheme)
    with obs.span("build", what="scheme_state", scheme=cfg.scheme):
        state = scheme.build(d, cfg.sim, cfg.capacity)
    stim = _resolve_dist_stimulus(d, cfg.sim, sugar_neurons, stimulus)
    probes, vrows, owner = _resolve_dist_probes(d, cfg.sim, probes)
    pad = jnp.asarray(d.inv_perm.reshape(d.n_parts, d.part_size) >= 0)
    carry0 = _init_dist_carry(d, cfg, stim, scheme, keys)
    if not emulate and mesh is None:
        mesh = make_core_mesh(d.n_parts)

    def run(carry, k, t0):
        if emulate:
            return _run_emulated(cfg.scheme, carry, state, stim, pad, vrows,
                                 cfg, probes, k, trials, t0)
        return _run_shard_map(cfg.scheme, carry, state, stim, pad, vrows,
                              cfg, probes, k, trials, mesh, t0)

    # a telemetry session routes single runs through the chunk driver
    # (one chunk when chunk_steps is None) for the per-chunk event
    # stream; the trial-batched path stays unsupervised (spans and
    # compile metrics still apply)
    supervised = (chunk_steps is not None or checkpoint_dir is not None
                  or cfg.sim.health is not None
                  or (obs.active() is not None and not trials))
    if not supervised:
        out, records = run(carry0, t_steps, None)
    else:
        if trials:
            raise ValueError(
                "chunked supervision (chunk_steps / checkpoint_dir / "
                "health) is not supported on the trial-batched path; "
                "supervise seeds as separate simulate_distributed runs")
        ckpt = (SimCheckpointer(checkpoint_dir, async_save=async_checkpoint)
                if checkpoint_dir is not None else None)
        out, records = run_chunked(
            lambda cy, s, k: run(cy, k, jnp.int32(s)),
            carry0, t_steps, chunk_steps,
            time_axis=1,            # records are partition-stacked [P, K, ..]
            health=cfg.sim.health, n=d.n_orig, dt_ms=cfg.sim.params.dt,
            checkpointer=ckpt, resume=resume,
            host_hook=getattr(scheme, "host_supervise", None))
    return out, records, probes, owner


# --------------------------------------------------------------------------
# Mapping partition-stacked results back to original neuron ids
# --------------------------------------------------------------------------

def _to_orig(d: DCSR, arr, dtype=None):
    """[P, *mid, U] partition-stacked -> [*mid, n_orig] in original ids;
    pad slots are dropped (they can never contribute — by construction)."""
    arr = np.asarray(arr)
    mid = arr.shape[1:-1]
    flat = np.moveaxis(arr, 0, -2).reshape(
        mid + (d.n_parts * d.part_size,))
    out = np.zeros(mid + (d.n_orig,), dtype=dtype or arr.dtype)
    valid = d.inv_perm >= 0
    out[..., d.inv_perm[valid]] = flat[..., valid]
    return out


def _assemble_records(d: DCSR, records: dict, probes, owner, n_real: int
                      ) -> dict:
    """Per-partition probe records [P, *mid, ...] -> monolithic-shaped
    records in original neuron ids."""
    out = {}
    for name, arr in records.items():
        arr = np.asarray(arr)
        if name == "raster":
            out[name] = _to_orig(d, arr)
        elif name == "v":
            # each partition traced every probe id against its own rows
            # (the record only exists when ids were probed); keep the
            # owning partition's trace per id
            out[name] = np.stack(
                [arr[owner[i], ..., i] for i in range(arr.shape[-1])],
                axis=-1)
        elif name == "pop_rate_hz":
            # per-partition mean over U (incl. inert pads) -> global mean
            # over the n_orig real neurons
            out[name] = arr.astype(np.float64).sum(axis=0) * (
                d.part_size / n_real)
        elif name == "dropped":
            out[name] = arr.sum(axis=0)
        else:                                   # scheme-agnostic fallback
            out[name] = arr.sum(axis=0)
    return out


def _assemble(d: DCSR, out: SimCarry, records: dict, probes, owner):
    counts = _to_orig(d, out.counts, dtype=np.int64)
    state = jax.tree.map(lambda x: _to_orig(d, x), out.lif)
    recs = _assemble_records(d, records, probes, owner, d.n_orig)
    stats = {k: np.asarray(v).sum(axis=0) for k, v in out.stats.items()}
    dropped = np.asarray(out.dropped).sum(axis=0)
    return counts, dropped, state, recs, stats


# --------------------------------------------------------------------------
# Entry points
# --------------------------------------------------------------------------

def simulate_distributed(
    d: DCSR,
    cfg: DistConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seed: int = 0,
    mesh: Mesh | None = None,
    emulate: bool = False,
    stimulus=None,
    probes=None,
    chunk_steps: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    async_checkpoint: bool = False,
) -> DistResult:
    """Run the partitioned network.  ``emulate=True`` uses vmap with an
    axis name on one device (semantics-identical); otherwise shard_map
    over a "cores" mesh axis with one partition per device.

    ``cfg.scheme`` selects a registered exchange scheme (see
    :func:`repro.core.exchange.available_schemes`).  ``stimulus`` is any
    stateless :class:`repro.exp.Stimulus` addressed in *original* neuron
    ids (sharded onto the partitioning here); ``probes`` any
    :class:`repro.exp.ProbeSpec`, with records returned in original ids
    exactly like :func:`repro.core.simulate`.  For a vmapped seed batch
    use :func:`repro.exp.run_dist_trials`.

    ``chunk_steps`` / ``checkpoint_dir`` / ``resume`` mirror
    :func:`repro.core.simulate`'s chunked supervision (bit-identical
    chunking, chunk-boundary health checks against ``cfg.sim.health``,
    checkpoint/resume) on the partitioned path; see ``docs/resilience.md``.
    With a telemetry session active (:func:`repro.obs.telemetry`) the run
    emits the same span/chunk/compile event stream as the monolithic
    path and surfaces the compile cache on
    ``DistResult.stats["compile_cache"]``; see ``docs/observability.md``.
    """
    tele = obs.active()
    with obs.span("simulate_distributed", scheme=cfg.scheme):
        if tele is not None:
            tele.emit("run_start", kind="simulate_distributed",
                      scheme=cfg.scheme, n=d.n_orig, t_steps=t_steps,
                      chunk_steps=chunk_steps,
                      fixed_point=cfg.sim.fixed_point)
        t_run = time.monotonic()
        keys = jax.random.split(jax.random.PRNGKey(seed), d.n_parts)
        out, records, probes, owner = _run_partitioned(
            d, cfg, t_steps, keys, sugar_neurons, stimulus, probes, mesh,
            emulate, trials=False, chunk_steps=chunk_steps,
            checkpoint_dir=checkpoint_dir, resume=resume,
            async_checkpoint=async_checkpoint)
        counts, dropped, state, recs, stats = _assemble(d, out, records,
                                                        probes, owner)
        if tele is not None:
            tele.emit("run_end", steps=t_steps,
                      wall_s=round(time.monotonic() - t_run, 6),
                      counters=carry_counters(out),
                      metrics=tele.metrics.counters())
            stats["compile_cache"] = tele.metrics.compile_snapshot()
    return DistResult(counts=counts, dropped=int(dropped), state=state,
                      raster=recs.get("raster"), records=recs, stats=stats)


__all__ = ["AXIS", "DistArrays", "DistConfig", "DistResult",
           "build_dist_arrays", "make_core_mesh", "simulate_distributed"]
