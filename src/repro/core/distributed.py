"""Distributed multi-core SNN simulation via shard_map (paper §3.2.2-3.2.3).

Maps DCSR partitions onto a device mesh axis ("cores"), one partition per
device, and exchanges spikes between partitions each delay window with one of
two communication schemes mirroring the paper's:

* ``bitmap`` — all_gather of the per-partition spike bitmap: one aggregated
  message per core pair, the shared-synaptic-delivery analogue.  Comm volume
  is fixed (P*U bits/step) regardless of activity; delivery cost ∝ local nnz.

* ``event``  — all_gather of fixed-capacity compacted active-neuron index
  lists: the spike-message analogue (shared axon routing sends one message
  per target core per spike; on a TPU mesh the all_gather of K event slots is
  the collective-native equivalent).  Comm volume ∝ activity (K ids/step);
  delivery cost ∝ events × their local fan-out (bounded by a synapse budget).
  The per-partition compaction and the bounded ragged gather are the same
  :mod:`repro.core.compaction` primitives the monolithic event engine runs
  (hierarchical O(U/128 + B_cap·128) compaction, shared ``ragged_slots``),
  and drops — budget overruns *and* spikes beyond the event capacity — are
  counted exactly in synapse units via the prebuilt global fan-out table.

Every partition is computationally self-contained except for the spike
exchange — exactly the paper's framing of the edge cut as a sparse,
data-dependent halo.

Stimulation flows through the same :mod:`repro.exp` stimulus pytrees as the
monolithic loop: :func:`repro.exp.shard_stimulus` remaps per-neuron leaves
onto the partitioning, and each partition steps the stimulus on its local
``[U]`` slab with its own PRNG stream (stateless stimuli only).

The same step function also runs unsharded under vmap (``emulate=True``) so
semantics are testable on one device; the shard_map path is exercised in
tests via a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .compaction import (derived_block_capacity, ragged_slots,
                         two_level_active)
from .dcsr import DCSR
from .engine import SimConfig
from .neuron import LIFState, init_state


# --------------------------------------------------------------------------
# Per-partition device arrays
# --------------------------------------------------------------------------

class DistArrays(NamedTuple):
    """Stacked per-partition synaptic state.  Leading dim = P (sharded)."""
    # target-major (bitmap scheme): local in-CSR with global source ids
    syn_src: jax.Array        # [P, S] int32 global new id; pad = P*U
    syn_tgt: jax.Array        # [P, S] int32 local target;  pad = U
    syn_w: jax.Array          # [P, S] float32
    # source-major (event scheme): per-partition fan-out of *global* sources
    # into local targets.  out_indptr[p, s] = start of global-source s's local
    # synapse run on partition p.
    out_indptr: jax.Array     # [P, P*U + 1] int32
    out_tgt: jax.Array        # [P, S] int32 local target; pad = U
    out_w: jax.Array          # [P, S] float32
    pad_mask: jax.Array       # [P, U] bool — True for real neurons
    src_gfo: jax.Array        # [P, U] int32 global fan-out of local sources
                              # (sum of their synapse runs over all
                              # partitions) — exact drop accounting for
                              # spikes beyond the event capacity


def build_dist_arrays(d: DCSR) -> DistArrays:
    P_, U, S = d.n_parts, d.part_size, d.s_max
    n_glob = P_ * U

    # event-scheme regroup: per partition, sort synapses by global source
    out_indptr = np.zeros((P_, n_glob + 1), dtype=np.int32)
    out_tgt = np.full((P_, S), U, dtype=np.int32)
    out_w = np.zeros((P_, S), dtype=np.float32)
    for p in range(P_):
        valid = d.syn_src[p] < n_glob
        src = d.syn_src[p][valid]
        tgt = d.syn_tgt_local[p][valid]
        w = d.syn_w[p][valid]
        order = np.argsort(src, kind="stable")
        src_s, tgt_s, w_s = src[order], tgt[order], w[order]
        m = len(src_s)
        out_tgt[p, :m] = tgt_s
        out_w[p, :m] = w_s
        counts = np.bincount(src_s, minlength=n_glob)
        np.cumsum(counts, out=out_indptr[p, 1:])

    pad = np.zeros((P_, U), dtype=bool)
    real = d.inv_perm.reshape(P_, U) >= 0
    pad[:] = real

    # global fan-out per source neuron = its local synapse-run length summed
    # over every partition's source-major indptr
    gfo = np.diff(out_indptr, axis=1).sum(axis=0).astype(np.int32)  # [P*U]

    return DistArrays(
        syn_src=jnp.asarray(d.syn_src),
        syn_tgt=jnp.asarray(d.syn_tgt_local),
        syn_w=jnp.asarray(d.syn_w),
        out_indptr=jnp.asarray(out_indptr),
        out_tgt=jnp.asarray(out_tgt),
        out_w=jnp.asarray(out_w),
        pad_mask=jnp.asarray(pad),
        src_gfo=jnp.asarray(gfo.reshape(P_, U)),
    )


# --------------------------------------------------------------------------
# Per-partition delivery
# --------------------------------------------------------------------------

def _deliver_bitmap(spk_global: jax.Array, arr_src, arr_tgt, arr_w, U: int
                    ) -> jax.Array:
    """spk_global: [P*U] bool; local in-CSR gather + segment_sum -> [U]."""
    spk_pad = jnp.concatenate([spk_global.astype(jnp.float32),
                               jnp.zeros((1,), jnp.float32)])
    contrib = arr_w * spk_pad[arr_src]
    return jax.ops.segment_sum(contrib, arr_tgt, num_segments=U + 1)[:U]


def _deliver_events(events: jax.Array, out_indptr, out_tgt, out_w,
                    U: int, n_glob: int, syn_budget: int
                    ) -> tuple[jax.Array, jax.Array]:
    """events: [E] global ids (pad = n_glob).  Bounded ragged gather via the
    shared :func:`repro.core.compaction.ragged_slots` — the same code path
    the monolithic event engine runs, applied to the all-gathered event
    list against this partition's source-major local store."""
    syn_ix, ok, total = ragged_slots(
        events, out_indptr, syn_budget,
        invalid_from=n_glob, gather_size=out_tgt.shape[0])
    contrib = jnp.where(ok, out_w[syn_ix], 0.0)
    tgt = jnp.where(ok, out_tgt[syn_ix], U)
    g = jax.ops.segment_sum(contrib, tgt, num_segments=U + 1)[:U]
    return g, jnp.maximum(total - syn_budget, 0)


# --------------------------------------------------------------------------
# The per-device step (works under shard_map or vmap)
# --------------------------------------------------------------------------

class DistCarry(NamedTuple):
    lif: LIFState          # leaves [U] per device
    ring: jax.Array        # [D, U] bool
    ptr: jax.Array         # i32 scalar
    key: jax.Array
    counts: jax.Array      # [U] int32
    dropped: jax.Array     # i32 scalar
    stim: tuple            # stimulus state (stateless stimuli: no leaves)


@dataclasses.dataclass(frozen=True)
class DistConfig:
    sim: SimConfig
    scheme: str = "event"        # "bitmap" | "event"
    spike_capacity: int = 256    # K per partition (event scheme)
    syn_budget: int = 32_768     # per-partition synapse budget per step
    block_capacity: int = 0      # active 128-blocks per partition (0=derive)


def _dist_step(carry: DistCarry, t, *, arrs: DistArrays, stim,
               cfg: DistConfig, P_: int, U: int, axis: str | None):
    """One simulation step on one partition.  `axis` names the mesh axis for
    collectives; None means the caller runs it under vmap with manual
    all-gather emulation (spmd_axis_name)."""
    from repro.exp.stimulus import apply_drive, n_split
    sc = cfg.sim
    p = sc.params
    keys = jax.random.split(carry.key, n_split(stim))
    delayed = carry.ring[carry.ptr]                      # [U] bool local

    n_glob = P_ * U
    if cfg.scheme == "bitmap":
        spk_all = jax.lax.all_gather(delayed, axis).reshape(n_glob)
        g_units = _deliver_bitmap(spk_all, arrs.syn_src, arrs.syn_tgt,
                                  arrs.syn_w, U)
        drop = jnp.int32(0)
    elif cfg.scheme == "event":
        bcap = cfg.block_capacity or derived_block_capacity(
            U, cfg.spike_capacity)
        idx = two_level_active(delayed, cfg.spike_capacity, bcap)
        my = jax.lax.axis_index(axis)
        gid = jnp.where(idx < U, idx + my * U, n_glob).astype(jnp.int32)
        events = jax.lax.all_gather(gid, axis).reshape(-1)   # [P*K]
        g_units, drop = _deliver_events(events, arrs.out_indptr, arrs.out_tgt,
                                        arrs.out_w, U, n_glob, cfg.syn_budget)
        # Spikes beyond the per-partition event capacity never enter any
        # partition's event list; count their *global* fan-out as dropped
        # synapses (exact, same units as the budget drops): requested minus
        # the fan-out of the spikes actually kept by the compaction.
        req_fo = jnp.sum(jnp.where(delayed, arrs.src_gfo, 0))
        kept_fo = jnp.sum(jnp.where(
            idx < U, arrs.src_gfo[jnp.minimum(idx, U - 1)], 0))
        drop = drop.astype(jnp.int32) + (req_fo - kept_fo)
    else:
        raise ValueError(cfg.scheme)

    sstate, drive = stim.step(carry.stim, keys[1:], t, U, p)
    lif, spikes = apply_drive(carry.lif, g_units, drive, p, sc.fixed_point)
    spikes = jnp.logical_and(spikes, arrs.pad_mask)      # pad neurons inert

    ring = carry.ring.at[carry.ptr].set(spikes)
    ptr = (carry.ptr + 1) % p.delay_steps
    new = DistCarry(lif=lif, ring=ring, ptr=ptr, key=keys[0],
                    counts=carry.counts + spikes.astype(jnp.int32),
                    dropped=carry.dropped + drop, stim=sstate)
    return new, None


class DistResult(NamedTuple):
    counts: np.ndarray      # [n_orig] spike counts mapped back to orig ids
    dropped: int


def make_core_mesh(n_cores: int, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    if len(devices) < n_cores:
        raise ValueError(f"need {n_cores} devices, have {len(devices)}")
    return Mesh(np.array(devices[:n_cores]), ("cores",))


def simulate_distributed(
    d: DCSR,
    cfg: DistConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seed: int = 0,
    mesh: Mesh | None = None,
    emulate: bool = False,
    stimulus=None,
) -> DistResult:
    """Run the partitioned network.  ``emulate=True`` uses vmap with
    spmd_axis_name on one device (semantics-identical); otherwise shard_map
    over a "cores" mesh axis with one partition per device.

    ``stimulus`` is any stateless :class:`repro.exp.Stimulus` addressed in
    *original* neuron ids; it is sharded onto the partitioning here.  The
    default reconstructs the legacy masked sugar-Poisson + background drive
    from ``cfg.sim`` and ``sugar_neurons``.
    """
    from repro.exp.stimulus import legacy_stimulus, shard_stimulus

    P_, U = d.n_parts, d.part_size
    arrs = build_dist_arrays(d)
    sc = cfg.sim
    if stimulus is None:
        stimulus = legacy_stimulus(sc, d.n_orig, sugar_idx=sugar_neurons,
                                   masked=True)
    elif sugar_neurons is not None:
        raise ValueError(
            "pass either sugar_neurons (legacy drive) or stimulus, "
            "not both — an explicit stimulus ignores sugar_neurons")
    stim = shard_stimulus(stimulus, d)

    lif0 = init_state(P_ * U, sc.params, sc.fixed_point)
    lif0 = jax.tree.map(lambda x: x.reshape(P_, U), lif0)
    keys = jax.random.split(jax.random.PRNGKey(seed), P_)
    carry0 = DistCarry(
        lif=lif0,
        ring=jnp.zeros((P_, sc.params.delay_steps, U), dtype=bool),
        ptr=jnp.zeros((P_,), jnp.int32),
        key=keys,
        counts=jnp.zeros((P_, U), jnp.int32),
        dropped=jnp.zeros((P_,), jnp.int32),
        stim=stim.init_state(U),
    )

    axis = "cores"

    def run_one(carry, arr, st):
        # scan over time on one device's partition
        def body(c, t):
            return _dist_step(c, t, arrs=arr, stim=st, cfg=cfg, P_=P_, U=U,
                              axis=axis)
        c, _ = jax.lax.scan(body, carry,
                            jnp.arange(t_steps, dtype=jnp.int32))
        return c

    if emulate:
        # vmap over the partition dim with a named axis -> collectives work
        out = jax.jit(jax.vmap(run_one, in_axes=(0, 0, 0), axis_name=axis)
                      )(carry0, arrs, stim)
    else:
        if mesh is None:
            mesh = make_core_mesh(P_)
        spec_carry = jax.tree.map(lambda _: P("cores"), carry0)
        spec_arr = jax.tree.map(lambda _: P("cores"), arrs)
        spec_stim = jax.tree.map(lambda _: P("cores"), stim)

        def sharded(carry, arr, st):
            carry = jax.tree.map(lambda x: x[0], carry)   # strip local P dim
            arr = jax.tree.map(lambda x: x[0], arr)
            st = jax.tree.map(lambda x: x[0], st)
            c = run_one(carry, arr, st)
            return jax.tree.map(lambda x: x[None], c)

        fn = shard_map(sharded, mesh=mesh,
                       in_specs=(spec_carry, spec_arr, spec_stim),
                       out_specs=spec_carry, check_rep=False)
        out = jax.jit(fn)(carry0, arrs, stim)

    counts_pu = np.asarray(out.counts).reshape(P_ * U)
    counts = np.zeros(d.n_orig, dtype=np.int64)
    valid = d.inv_perm >= 0
    counts[d.inv_perm[valid]] = counts_pu[valid]
    return DistResult(counts=counts, dropped=int(np.asarray(out.dropped).sum()))
