"""Connectome container and FlyWire-statistics synthetic generator.

The paper simulates the FlyWire adult Drosophila connectome (139,255 neurons,
~15M condensed synapses; 50M raw) as a flat irregular graph.  The real parquet
dump is not redistributable offline, so this module provides:

  * :class:`Connectome` — an immutable container with CSR views by target
    (fan-in) and by source (fan-out), plus the summary statistics the paper's
    figures are drawn from (Figs 2, 3).
  * :func:`synthetic_flywire` — a statistics-matched synthetic generator:
    log-normal out-degree with a heavy tail (max fan-out ~9.8k), preferential
    attachment for in-degree (max fan-in ~10.4k), signed integer weights
    dominated by ±1 with outliers up to [-2405, 1897], Dale's law per source
    neuron.
  * :func:`load_flywire_parquet` — loader for the real data when present.

All arrays are numpy on host; JAX engines consume device views built from
these (see :mod:`repro.core.engine`).
"""

from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Optional

import numpy as np

# Paper constants (Section 3.1)
FLYWIRE_N_NEURONS = 139_255
FLYWIRE_N_SYNAPSES = 15_000_000  # condensed (same-pair synapses merged)
FLYWIRE_MAX_FAN_IN = 10_356
FLYWIRE_MAX_FAN_OUT = 9_783
FLYWIRE_W_MIN = -2405
FLYWIRE_W_MAX = 1897


@dataclasses.dataclass(frozen=True)
class Connectome:
    """Flat irregular synapse graph in target-major CSR plus source-major CSR.

    Attributes:
      n: number of neurons.
      in_indptr:  [n+1] CSR row pointers, target-major (fan-in lists).
      in_indices: [nnz] source neuron id per synapse, grouped by target.
      in_weights: [nnz] integer weight per synapse (signed; excitatory > 0).
      out_indptr / out_indices / out_weights: source-major transpose
        (fan-out lists; out_weights[k] is the weight of the synapse onto
        out_indices[k]).
    """

    n: int
    in_indptr: np.ndarray
    in_indices: np.ndarray
    in_weights: np.ndarray
    out_indptr: np.ndarray
    out_indices: np.ndarray
    out_weights: np.ndarray

    @property
    def nnz(self) -> int:
        return int(self.in_indices.shape[0])

    @property
    def fan_in(self) -> np.ndarray:
        return np.diff(self.in_indptr)

    @property
    def fan_out(self) -> np.ndarray:
        return np.diff(self.out_indptr)

    def stats(self) -> dict:
        w = self.in_weights
        return {
            "n_neurons": self.n,
            "n_synapses": self.nnz,
            "max_fan_in": int(self.fan_in.max()) if self.nnz else 0,
            "max_fan_out": int(self.fan_out.max()) if self.nnz else 0,
            "mean_fan_in": float(self.fan_in.mean()) if self.nnz else 0.0,
            "w_min": int(w.min()) if self.nnz else 0,
            "w_max": int(w.max()) if self.nnz else 0,
            "frac_w_pm1": float(np.mean(np.abs(w) == 1)) if self.nnz else 0.0,
            "frac_inhibitory": float(np.mean(w < 0)) if self.nnz else 0.0,
        }

    def validate(self) -> None:
        assert self.in_indptr.shape == (self.n + 1,)
        assert self.out_indptr.shape == (self.n + 1,)
        assert self.in_indptr[0] == 0 and self.in_indptr[-1] == self.nnz
        assert self.out_indptr[-1] == self.nnz
        assert np.all(np.diff(self.in_indptr) >= 0)
        assert np.all(np.diff(self.out_indptr) >= 0)
        if self.nnz:
            assert self.in_indices.min() >= 0
            assert self.in_indices.max() < self.n
            assert self.out_indices.max() < self.n

    def dense(self, dtype=np.float32) -> np.ndarray:
        """Dense [n, n] weight matrix W with W[target, source] — test-scale only."""
        if self.n > 20_000:
            raise ValueError("dense() is for test-scale connectomes only")
        w = np.zeros((self.n, self.n), dtype=dtype)
        tgt = np.repeat(np.arange(self.n), self.fan_in)
        w[tgt, self.in_indices] = self.in_weights.astype(dtype)
        return w


def _transpose_csr(n, indptr, indices, weights):
    """target-major CSR -> source-major CSR (or vice versa)."""
    counts = np.bincount(indices, minlength=n)
    t_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=t_indptr[1:])
    order = np.argsort(indices, kind="stable")
    rows = np.repeat(np.arange(n), np.diff(indptr))
    t_indices = rows[order].astype(indices.dtype)
    t_weights = weights[order]
    return t_indptr, t_indices, t_weights


def from_edges(
    n: int, pre: np.ndarray, post: np.ndarray, weight: np.ndarray
) -> Connectome:
    """Build a Connectome from a flat (pre, post, weight) edge table.

    Same-pair duplicates are condensed by summing weights (the paper's
    simplification from 50M raw to ~15M condensed synapses).
    """
    pre = np.asarray(pre, dtype=np.int64)
    post = np.asarray(post, dtype=np.int64)
    weight = np.asarray(weight)
    # Condense duplicates: sort by (post, pre) and segment-sum weights.
    key = post * n + pre
    order = np.argsort(key, kind="stable")
    key_s, pre_s, post_s, w_s = key[order], pre[order], post[order], weight[order]
    uniq_mask = np.empty(key_s.shape, dtype=bool)
    uniq_mask[0:1] = True
    np.not_equal(key_s[1:], key_s[:-1], out=uniq_mask[1:])
    seg_ids = np.cumsum(uniq_mask) - 1
    w_c = np.zeros(int(seg_ids[-1]) + 1 if len(seg_ids) else 0, dtype=np.int64)
    np.add.at(w_c, seg_ids, w_s)
    pre_c = pre_s[uniq_mask]
    post_c = post_s[uniq_mask]
    # target-major CSR
    counts = np.bincount(post_c, minlength=n)
    in_indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=in_indptr[1:])
    in_indices = pre_c.astype(np.int32)
    in_weights = w_c.astype(np.int32)
    out_indptr, out_indices, out_weights = _transpose_csr(
        n, in_indptr, in_indices, in_weights
    )
    c = Connectome(
        n=n,
        in_indptr=in_indptr,
        in_indices=in_indices,
        in_weights=in_weights,
        out_indptr=out_indptr,
        out_indices=out_indices.astype(np.int32),
        out_weights=out_weights,
    )
    c.validate()
    return c


def synthetic_flywire(
    n: int = FLYWIRE_N_NEURONS,
    target_synapses: Optional[int] = None,
    seed: int = 0,
    frac_inhibitory: float = 0.30,
    frac_pm1: float = 0.45,
    max_abs_weight_exc: int = FLYWIRE_W_MAX,
    max_abs_weight_inh: int = -FLYWIRE_W_MIN,
) -> Connectome:
    """Generate a synthetic connectome with FlyWire-like statistics.

    Degree model: out-degree ~ LogNormal tuned so mean degree matches
    `target_synapses / n`, clipped to [1, ~0.07n]; targets drawn with
    preferential attachment (in-attractiveness ~ LogNormal(1.0)) producing a
    heavy-tailed in-degree.  Weight model: |w| = 1 with prob `frac_pm1`, else
    1 + Geometric tail scaled into the paper's outlier range.  Dale's law:
    each source is excitatory or inhibitory for all its synapses.
    """
    rng = np.random.default_rng(seed)
    if target_synapses is None:
        target_synapses = int(n * FLYWIRE_N_SYNAPSES / FLYWIRE_N_NEURONS)
    mean_deg = target_synapses / n

    # --- out-degrees: lognormal with heavy tail, mean ~= mean_deg ---
    sigma = 1.1
    mu = np.log(mean_deg) - sigma**2 / 2
    deg = rng.lognormal(mu, sigma, size=n)
    # a few extreme-fan-out outliers (paper: max 9,783 at full scale)
    n_out = max(1, n // 2000)
    hi = min(0.07 * n, FLYWIRE_MAX_FAN_OUT)
    deg[rng.choice(n, n_out, replace=False)] = rng.uniform(0.5 * hi, hi, n_out)
    deg = np.clip(deg, 1, hi).astype(np.int64)
    # trim/pad to the synapse budget
    scale = target_synapses / deg.sum()
    deg = np.maximum(1, (deg * scale).astype(np.int64))
    nnz = int(deg.sum())

    # --- targets: preferential attachment ---
    attract = rng.lognormal(0.0, 1.0, size=n)
    n_in_out = max(1, n // 2000)
    attract[rng.choice(n, n_in_out, replace=False)] *= 40.0  # fan-in outliers
    p = attract / attract.sum()
    pre = np.repeat(np.arange(n, dtype=np.int64), deg)
    post = rng.choice(n, size=nnz, p=p).astype(np.int64)
    # no self-synapses: re-draw collisions cheaply by offsetting
    self_mask = pre == post
    post[self_mask] = (post[self_mask] + 1) % n

    # --- weights ---
    mag = np.ones(nnz, dtype=np.int64)
    tail = rng.random(nnz) >= frac_pm1
    # geometric body (2..~100 dominates) + rare large outliers
    body = 1 + rng.geometric(0.08, size=nnz)
    mag = np.where(tail, body, mag)
    out_mask = rng.random(nnz) < 2e-5
    mag = np.where(out_mask, rng.integers(300, max_abs_weight_exc, size=nnz), mag)
    inhibitory_src = rng.random(n) < frac_inhibitory
    sign = np.where(inhibitory_src[pre], -1, 1)
    w = sign * np.minimum(
        mag, np.where(sign < 0, max_abs_weight_inh, max_abs_weight_exc)
    )
    return from_edges(n, pre, post, w)


def load_flywire_parquet(path: str) -> Connectome:
    """Load the real FlyWire connectivity table (columns: pre_root_id,
    post_root_id, syn_count or weight).  Requires pyarrow/pandas at runtime."""
    import importlib

    pq = importlib.import_module("pyarrow.parquet")  # pragma: no cover
    tbl = pq.read_table(path).to_pydict()  # pragma: no cover
    pre_ids = np.asarray(tbl["pre_root_id"])  # pragma: no cover
    post_ids = np.asarray(tbl["post_root_id"])  # pragma: no cover
    w = np.asarray(tbl.get("weight", tbl.get("syn_count")))  # pragma: no cover
    uniq, inv = np.unique(
        np.concatenate([pre_ids, post_ids]), return_inverse=True
    )  # pragma: no cover
    n = len(uniq)  # pragma: no cover
    pre = inv[: len(pre_ids)]  # pragma: no cover
    post = inv[len(pre_ids):]  # pragma: no cover
    return from_edges(n, pre, post, w)  # pragma: no cover


def cache_path(n: int, seed: int, **kw) -> str:
    """Cache filename for a synthetic connectome.

    Any generator kwargs beyond (n, seed) — target_synapses, frac_inhibitory,
    ... — are folded into a digest so differently-parameterized connectomes
    never collide in the cache (kwarg-free calls keep the legacy name).
    """
    base = f"connectome_{n}_{seed}"
    if kw:
        digest = hashlib.md5(
            repr(sorted(kw.items())).encode()).hexdigest()[:10]
        base += f"_{digest}"
    return os.path.join(
        os.environ.get("REPRO_CACHE", "/tmp/repro_cache"), base + ".npz"
    )


def synthetic_flywire_cached(n: int, seed: int = 0, **kw) -> Connectome:
    """Disk-cached synthetic connectome (full-scale generation takes ~min).
    The cache key covers every generator kwarg, not just (n, seed)."""
    path = cache_path(n, seed, **kw)
    if os.path.exists(path):
        z = np.load(path)
        return Connectome(n=int(z["n"]), **{
            k: z[k] for k in ("in_indptr", "in_indices", "in_weights",
                              "out_indptr", "out_indices", "out_weights")})
    c = synthetic_flywire(n=n, seed=seed, **kw)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    np.savez_compressed(
        path, n=c.n, in_indptr=c.in_indptr, in_indices=c.in_indices,
        in_weights=c.in_weights, out_indptr=c.out_indptr,
        out_indices=c.out_indices, out_weights=c.out_weights)
    return c
