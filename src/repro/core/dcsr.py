"""SNN-dCSR: the partitioned intermediate representation (paper §3.1.1/§3.2.2).

STACS moves the network from "global and unified" (one big CSR) to "global
and distributed" (per-partition compact adjacency lists with a cumulative
neuron-distribution list, neuron ids renumbered to be sequential in partition
order).  From there, computing core-local routing structures is
straightforward.  We reproduce that exactly:

* neurons are renumbered so partition p owns the contiguous id range
  [p*U, p*U + U) where U = padded per-partition neuron count (TPU shards need
  uniform extents — the padding neurons have no synapses and never spike);
* per-partition synapse lists are stacked into uniform [P, S_max] arrays
  (target-local, source-global) — the shard_map runtime consumes these
  directly.

This is the single source of truth both for the distributed simulator
(:mod:`repro.core.distributed`) and for the Loihi-style memory audit
(:func:`repro.core.partition.partition_report`).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .compress import quantize_weights
from .connectome import Connectome
from .partition import Partitioning


@dataclasses.dataclass(frozen=True)
class DCSR:
    """Partitioned, renumbered, padded network snapshot."""

    n_orig: int
    n_parts: int                # P
    part_size: int              # U (uniform, padded)
    perm: np.ndarray            # [n_orig] orig id -> new global id
    inv_perm: np.ndarray        # [P*U] new global id -> orig id (or -1 for pad)
    # synapses, stacked per partition (pad slots: src = P*U, tgt_local = U, w=0)
    syn_src: np.ndarray         # [P, S_max] int32 source NEW-global id
    syn_tgt_local: np.ndarray   # [P, S_max] int32 target local id in [0, U)
    syn_w: np.ndarray           # [P, S_max] float32 weight (weight units)
    s_max: int
    cum_neurons: np.ndarray     # [P+1] cumulative ORIGINAL neurons per part

    @property
    def n_padded(self) -> int:
        return self.n_parts * self.part_size


def build_dcsr(c: Connectome, p: Partitioning,
               quantize_bits: int | None = None,
               lane_multiple: int = 8) -> DCSR:
    n, P = c.n, p.n_parts
    sizes = np.diff(p.offsets)
    U = int(sizes.max())
    U = ((U + lane_multiple - 1) // lane_multiple) * lane_multiple

    # renumbering: orig id i in partition p at local position (i - offsets[p])
    part = p.part_of_neuron.astype(np.int64)
    local = np.arange(n, dtype=np.int64) - p.offsets[part]
    perm = part * U + local
    inv_perm = np.full(P * U, -1, dtype=np.int64)
    inv_perm[perm] = np.arange(n)

    w = c.in_weights
    if quantize_bits is not None:
        w = quantize_weights(w, quantize_bits)

    # group synapses by target partition
    tgt = np.repeat(np.arange(n, dtype=np.int64), c.fan_in)
    src = c.in_indices.astype(np.int64)
    tgt_part = part[tgt]
    order = np.argsort(tgt_part, kind="stable")
    tgt_s, src_s, w_s, part_s = tgt[order], src[order], w[order], tgt_part[order]
    counts = np.bincount(part_s, minlength=P)
    S_max = int(counts.max()) if len(counts) else 1
    S_max = ((S_max + lane_multiple - 1) // lane_multiple) * lane_multiple

    syn_src = np.full((P, S_max), P * U, dtype=np.int32)
    syn_tgt = np.full((P, S_max), U, dtype=np.int32)
    syn_w = np.zeros((P, S_max), dtype=np.float32)
    starts = np.zeros(P + 1, dtype=np.int64)
    np.cumsum(counts, out=starts[1:])
    for q in range(P):
        s, e = starts[q], starts[q + 1]
        m = e - s
        syn_src[q, :m] = perm[src_s[s:e]]
        syn_tgt[q, :m] = (perm[tgt_s[s:e]] - q * U)
        syn_w[q, :m] = w_s[s:e]

    cum = np.zeros(P + 1, dtype=np.int64)
    np.cumsum(sizes, out=cum[1:])
    return DCSR(n_orig=n, n_parts=P, part_size=U, perm=perm, inv_perm=inv_perm,
                syn_src=syn_src, syn_tgt_local=syn_tgt, syn_w=syn_w,
                s_max=S_max, cum_neurons=cum)


def edge_cut(d: DCSR) -> dict:
    """Exchange-neighbourhood statistics: fraction of synapses whose source
    lives on a different partition (the halo the comm schemes must cover)."""
    P, U = d.n_parts, d.part_size
    src_part = np.clip(d.syn_src // U, 0, P - 1)
    valid = d.syn_src < P * U
    local = (src_part == np.arange(P)[:, None]) & valid
    n_valid = int(valid.sum())
    return {
        "n_synapses": n_valid,
        "frac_remote": 1.0 - float(local.sum()) / max(1, n_valid),
        "s_max": d.s_max,
        "part_size": d.part_size,
    }
