"""Statistical validation: spike-rate parity between implementations.

The paper validates Brian2 ↔ STACS ↔ Loihi by plotting per-neuron average
spike rates (over 10 trials) against each other and checking they fall on the
y=x parity line (Figs 6, 12, 14, 15).  We reproduce the statistic and add
quantitative summaries (parity RMSE, Pearson r, fraction within tolerance).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ParityStats:
    rmse_hz: float
    pearson_r: float
    frac_within_1hz: float
    mean_rate_a: float
    mean_rate_b: float
    n_active: int
    n_nonfinite: int = 0   # entries masked out (NaN/inf in either input)

    def summary(self) -> str:
        return (f"rmse={self.rmse_hz:.3f}Hz r={self.pearson_r:.4f} "
                f"within1Hz={self.frac_within_1hz:.3f} "
                f"mean_a={self.mean_rate_a:.2f}Hz mean_b={self.mean_rate_b:.2f}Hz "
                f"active={self.n_active} nonfinite={self.n_nonfinite}")


def parity(rates_a: np.ndarray, rates_b: np.ndarray,
           active_thresh_hz: float = 0.5) -> ParityStats:
    """Compare index-matched per-neuron rates (averaged over trials).

    Non-finite entries (a poisoned run fed in by accident — see
    :mod:`repro.core.health`) are excluded from every statistic rather
    than silently propagating NaN into all of them; the count is reported
    as ``n_nonfinite`` so the caller can refuse a poisoned comparison."""
    rates_a = np.asarray(rates_a, np.float64)
    rates_b = np.asarray(rates_b, np.float64)
    finite = np.isfinite(rates_a) & np.isfinite(rates_b)
    n_nonfinite = int((~finite).sum())
    active = ((rates_a > active_thresh_hz) | (rates_b > active_thresh_hz)) \
        & finite
    a, b = rates_a[active], rates_b[active]
    if len(a) == 0:
        return ParityStats(0.0, 1.0, 1.0, 0.0, 0.0, 0,
                           n_nonfinite=n_nonfinite)
    rmse = float(np.sqrt(np.mean((a - b) ** 2)))
    if np.std(a) > 0 and np.std(b) > 0:
        r = float(np.corrcoef(a, b)[0, 1])
    else:
        r = 1.0 if np.allclose(a, b) else 0.0
    return ParityStats(
        rmse_hz=rmse,
        pearson_r=r,
        frac_within_1hz=float(np.mean(np.abs(a - b) <= 1.0)),
        mean_rate_a=float(a.mean()),
        mean_rate_b=float(b.mean()),
        n_active=int(active.sum()),
        n_nonfinite=n_nonfinite,
    )


def mean_rates_over_trials(count_trials: list[np.ndarray], t_steps: int,
                           dt_ms: float) -> np.ndarray:
    """[trials][n] spike counts -> [n] mean rate in Hz."""
    c = np.stack([np.asarray(x) for x in count_trials])
    return c.mean(axis=0) / (t_steps * dt_ms * 1e-3)


def raster_to_times(raster: np.ndarray, dt_ms: float):
    """[T, n] bool -> (times_ms, neuron_ids) for raster plots/dumps."""
    t, nid = np.nonzero(np.asarray(raster))
    return t * dt_ms, nid
