"""Connectome LIF simulation loop over pluggable delivery engines.

Synaptic-delivery strategies live in :mod:`repro.core.engines` (one module
per strategy, registered by name); this module owns everything engine-
independent: the LIF state machine (float or fixed-point), the ring-buffer
implementation of the uniform 1.8 ms synaptic delay, Poisson/background
drive, and the scan over timesteps.

The whole run is a single jitted computation per (engine, config, t_steps)
triple: device synaptic state is built once per :func:`simulate` call, the
carry (ring buffer + LIF state + counters) is donated so XLA updates it in
place across calls, and repeated calls with the same static signature skip
retracing entirely — the property the benchmark harness relies on.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .connectome import Connectome
from .engines import available_engines, get_engine
from .neuron import LIFParams, LIFState, init_state, lif_step, lif_step_fx


@dataclasses.dataclass(frozen=True)
class SimConfig:
    params: LIFParams = LIFParams()
    engine: str = "csr"             # see repro.core.engines / docs/engines.md
    fixed_point: bool = False
    quantize_bits: Optional[int] = None   # 9 = Loihi; None = raw weights
    poisson_to_v: bool = True       # True = Brian2 semantics; False = Loihi approx
    poisson_rate_hz: float = 150.0
    poisson_weight: float = 180.0   # weight units delivered per Poisson event
    background_rate_hz: float = 0.0  # scaling-study probabilistic spiking
    spike_capacity: int = 512        # K: max active neurons per step (event)
    syn_budget: int = 65_536         # S_cap: max delivered synapses per step
    ell_width_cap: int = 4096        # SSD fan-in cap
    collect_raster: bool = False


def build_synapses(c: Connectome, cfg: SimConfig) -> Any:
    """Build the device-resident synaptic state for ``cfg.engine``.

    Returns the engine-specific state pytree; pass it back to
    :func:`simulate` via ``syn=`` to amortize the host-side build across
    repeated runs (benchmark pattern)."""
    return get_engine(cfg.engine).build(c, cfg)


# --------------------------------------------------------------------------
# Full simulation loop
# --------------------------------------------------------------------------

class SimCarry(NamedTuple):
    lif: LIFState
    ring: jax.Array        # [D, n] bool delayed-spike ring buffer
    ptr: jax.Array         # scalar int32
    key: jax.Array
    counts: jax.Array      # [n] int32 spike counts
    dropped: jax.Array     # scalar int32 total dropped synapse events


class SimResult(NamedTuple):
    counts: jax.Array
    state: LIFState
    dropped: jax.Array
    raster: jax.Array | None


@functools.partial(jax.jit, static_argnums=(3, 4, 5),
                   donate_argnums=(1,))
def _run_scan(syn, carry: SimCarry, sugar_idx: jax.Array | None,
              cfg: SimConfig, t_steps: int, n: int):
    """One fused computation: scan `t_steps` LIF+delivery steps.

    ``syn`` is the engine state pytree (its static fields key the jit
    cache), ``carry`` is donated so ring/LIF buffers are updated in place.
    """
    p = cfg.params
    deliver = get_engine(cfg.engine).deliver
    # Per-step constants, hoisted out of the step body once per trace.
    p_sugar = cfg.poisson_rate_hz * p.dt * 1e-3
    p_bg = cfg.background_rate_hz * p.dt * 1e-3
    v_amp = p.v_th * 1.5
    v_amp_fx = round(v_amp / p.w_scale)

    def step(carry: SimCarry, _):
        key, k_poisson, k_bg = jax.random.split(carry.key, 3)
        delayed = carry.ring[carry.ptr]
        g_units, drop = deliver(syn, delayed, cfg)

        v_in = None
        v_in_fx = None
        force = None
        if sugar_idx is not None:
            # Draw only for the driven subset (|sugar| << n) and scatter.
            draws = jax.random.bernoulli(
                k_poisson, p_sugar, sugar_idx.shape)
            if cfg.poisson_to_v:
                if cfg.fixed_point:
                    v_in_fx = jnp.zeros(n, jnp.int32).at[sugar_idx].set(
                        draws.astype(jnp.int32) * v_amp_fx)
                else:
                    v_in = jnp.zeros(n, jnp.float32).at[sugar_idx].set(
                        draws.astype(jnp.float32) * v_amp)
            else:
                g_units = g_units.at[sugar_idx].add(
                    draws.astype(jnp.float32) * cfg.poisson_weight)
        if cfg.background_rate_hz > 0:
            force = jax.random.bernoulli(k_bg, p_bg, (n,))

        if cfg.fixed_point:
            g_in = jnp.round(g_units).astype(jnp.int32)
            lif, spikes = lif_step_fx(carry.lif, g_in, p, v_in_fx, force)
        else:
            lif, spikes = lif_step(carry.lif, g_units * p.w_scale, p, v_in,
                                   force)

        ring = carry.ring.at[carry.ptr].set(spikes)
        ptr = (carry.ptr + 1) % p.delay_steps
        counts = carry.counts + spikes.astype(jnp.int32)
        new = SimCarry(lif=lif, ring=ring, ptr=ptr, key=key, counts=counts,
                       dropped=carry.dropped + drop.astype(jnp.int32))
        return new, (spikes if cfg.collect_raster else None)

    return jax.lax.scan(step, carry, None, length=t_steps)


def simulate(
    c: Connectome,
    cfg: SimConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seed: int = 0,
    syn: Any | None = None,
) -> SimResult:
    """Run `t_steps` of the network; returns per-neuron spike counts (the
    paper's validation statistic) and optionally the full raster.

    ``cfg.engine`` selects a registered delivery engine (see
    :func:`repro.core.engines.available_engines`); ``syn`` optionally
    supplies a prebuilt state from :func:`build_synapses`.
    """
    n = c.n
    if syn is None:
        syn = build_synapses(c, cfg)
    sugar_idx = None
    if sugar_neurons is not None:
        sugar_idx = jnp.asarray(np.asarray(sugar_neurons).astype(np.int32))

    carry = SimCarry(
        lif=init_state(n, cfg.params, cfg.fixed_point),
        ring=jnp.zeros((cfg.params.delay_steps, n), dtype=bool),
        ptr=jnp.int32(0),
        key=jax.random.PRNGKey(seed),
        counts=jnp.zeros(n, jnp.int32),
        dropped=jnp.int32(0),
    )
    carry, raster = _run_scan(syn, carry, sugar_idx, cfg, t_steps, n)
    return SimResult(counts=carry.counts, state=carry.lif,
                     dropped=carry.dropped, raster=raster)


def spike_rates_hz(counts: jax.Array, t_steps: int, dt_ms: float) -> jax.Array:
    return counts.astype(jnp.float32) / (t_steps * dt_ms * 1e-3)


__all__ = ["SimConfig", "SimCarry", "SimResult", "available_engines",
           "build_synapses", "simulate", "spike_rates_hz"]
