"""Connectome LIF simulation loop over pluggable delivery engines.

Synaptic-delivery strategies live in :mod:`repro.core.engines` (one module
per strategy, registered by name); stimulation and observability live in
:mod:`repro.exp` (stimulus protocols, in-scan probes).  This module owns
everything that is engine- and stimulus-independent: the LIF state machine
(float or fixed-point), the ring-buffer implementation of the uniform
1.8 ms synaptic delay, and the scan over timesteps.

The whole run is a single jitted computation per (engine, stimulus, config,
probes, t_steps) signature: device synaptic state is built once per
:func:`simulate` call, the carry (ring buffer + LIF state + counters +
stimulus state) is donated so XLA updates it in place across calls, and
repeated calls with the same static signature skip retracing entirely — the
property the benchmark harness relies on.  :func:`repro.exp.run_trials`
vmaps the same scan over a seed batch for the paper's trial-averaged
statistics.
"""

from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from .capacity import MONOLITHIC_CAPACITY, CapacityConfig, merge_legacy_capacity
from .connectome import Connectome
from .engines import available_engines, get_engine
from .health import (HealthConfig, SimCheckpointer, carry_counters,
                     health_stats_init, run_chunked)
from .neuron import LIFParams, LIFState, init_state
from .step import SimCarry, scan_steps


@dataclasses.dataclass(frozen=True)
class SimConfig:
    params: LIFParams = LIFParams()
    engine: str = "csr"             # see repro.core.engines / docs/engines.md
    fixed_point: bool = False
    quantize_bits: Optional[int] = None   # 9 = Loihi; None = raw weights
    # Legacy stimulus fields: consumed by repro.exp.stimulus.legacy_stimulus
    # when simulate() is called without an explicit stimulus.
    poisson_to_v: bool = True       # True = Brian2 semantics; False = Loihi approx
    poisson_rate_hz: float = 150.0
    poisson_weight: float = 180.0   # weight units delivered per Poisson event
    background_rate_hz: float = 0.0  # scaling-study probabilistic spiking
    # Deprecated capacity shims -> capacity (CapacityConfig); explicit
    # writes warn and merge into .capacity, which is the one read path.
    spike_capacity: Optional[int] = None
    syn_budget: Optional[int] = None
    block_capacity: Optional[int] = None
    ell_width_cap: int = 4096        # SSD fan-in cap
    collect_raster: bool = False     # deprecated: use ProbeSpec(raster=True)
    capacity: Optional[CapacityConfig] = None   # event-path static budgets
    health: Optional[HealthConfig] = None   # in-scan sentinels + thresholds

    def __post_init__(self):
        cap = merge_legacy_capacity(
            self.capacity, self.spike_capacity, self.syn_budget,
            self.block_capacity, MONOLITHIC_CAPACITY, "SimConfig")
        object.__setattr__(self, "capacity", cap)
        # consume the shims: dataclasses.replace must never re-apply them
        for f in ("spike_capacity", "syn_budget", "block_capacity"):
            object.__setattr__(self, f, None)
        if self.collect_raster:
            warnings.warn(
                "SimConfig(collect_raster=True) is deprecated; pass "
                "probes=ProbeSpec(raster=True) instead",
                DeprecationWarning, stacklevel=3)


def build_synapses(c: Connectome, cfg: SimConfig) -> Any:
    """Build the device-resident synaptic state for ``cfg.engine``.

    Returns the engine-specific state pytree; pass it back to
    :func:`simulate` via ``syn=`` to amortize the host-side build across
    repeated runs (benchmark pattern)."""
    with obs.span("build", what="synapses", engine=cfg.engine):
        return get_engine(cfg.engine).build(c, cfg)


# --------------------------------------------------------------------------
# Full simulation loop
# --------------------------------------------------------------------------

class SimResult(NamedTuple):
    counts: jax.Array
    state: LIFState
    dropped: jax.Array
    raster: jax.Array | None
    records: dict          # ProbeSpec-selected [T, ...] arrays
    stats: dict = {}       # scheme + health counters (repro.core.health)


def _scan_steps(syn, carry: SimCarry, stim, cfg: SimConfig, probes,
                t_steps: int, n: int, t0=None):
    """Scan `t_steps` steps of the ONE step body (:mod:`repro.core.step`)
    through the degenerate P=1 ``local`` exchange scheme; shared by the
    single-run and vmapped-trials entry points.

    ``syn`` is the engine state pytree and ``stim`` the stimulus pytree
    (their static fields key the jit cache); all stimulus-specific work —
    Poisson drive, background spiking, clocked currents — flows through
    ``stim.step``, all observability through ``probes.collect``.  ``t0``
    is a *traced* step offset: a chunked run reuses one compiled K-step
    program for every chunk.
    """
    from .exchange import Topology, get_scheme
    return scan_steps(get_scheme("local"), syn, carry, stim, cfg,
                      cfg.capacity, Topology(1, n, axis=None), probes,
                      t_steps, t0=t0)


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6), donate_argnums=(1,))
def _run_scan_jit(syn, carry: SimCarry, stim, cfg: SimConfig, probes,
                  t_steps: int, n: int, t0=None):
    return _scan_steps(syn, carry, stim, cfg, probes, t_steps, n, t0)


@functools.partial(jax.jit, static_argnums=(3, 4, 5, 6), donate_argnums=(1,))
def _run_scan_trials_jit(syn, carry: SimCarry, stim, cfg: SimConfig, probes,
                         t_steps: int, n: int, t0=None):
    """Batched trials: vmap the scan over a leading seed/trial axis of the
    carry; syn and stim are broadcast (shared across trials)."""
    return jax.vmap(
        lambda cy: _scan_steps(syn, cy, stim, cfg, probes, t_steps, n, t0)
    )(carry)


# Compile-cache instrumentation (repro.obs): with a telemetry session
# active, calls are keyed per signature with hit/miss counters and
# per-signature trace/compile wall + cost_analysis — the ROADMAP's
# "surface hit rates".  Without a session these are the plain jit calls.
_run_scan = obs.InstrumentedJit(_run_scan_jit, "engine.run_scan",
                                static_argnums=(3, 4, 5, 6))
_run_scan_trials = obs.InstrumentedJit(_run_scan_trials_jit,
                                       "engine.run_trials",
                                       static_argnums=(3, 4, 5, 6))


def _init_carry(n: int, cfg: SimConfig, stimulus, seed: int) -> SimCarry:
    return SimCarry(
        lif=init_state(n, cfg.params, cfg.fixed_point),
        ring=jnp.zeros((cfg.params.delay_steps, n), dtype=bool),
        ptr=jnp.int32(0),
        key=jax.random.PRNGKey(seed),
        counts=jnp.zeros(n, jnp.int32),
        dropped=jnp.int32(0),
        stim=stimulus.init_state(n),
        stats=health_stats_init(cfg),
    )


def _resolve_stimulus(cfg: SimConfig, n: int, sugar_neurons, stimulus):
    if stimulus is not None:
        if sugar_neurons is not None:
            raise ValueError(
                "pass either sugar_neurons (legacy drive) or stimulus, "
                "not both — an explicit stimulus ignores sugar_neurons")
        return stimulus
    from repro.exp.stimulus import legacy_stimulus
    sugar_idx = None
    if sugar_neurons is not None:
        warnings.warn(
            "sugar_neurons= is deprecated; pass stimulus= instead (e.g. "
            "repro.exp.PoissonDrive(idx=...) or legacy_stimulus(cfg, n, "
            "sugar_idx))", DeprecationWarning, stacklevel=3)
        sugar_idx = np.asarray(sugar_neurons).astype(np.int32)
    return legacy_stimulus(cfg, n, sugar_idx)


def _resolve_probes(cfg: SimConfig, probes):
    if probes is not None:
        return probes
    from repro.exp.probes import ProbeSpec
    return ProbeSpec(raster=cfg.collect_raster)


def simulate(
    c: Connectome,
    cfg: SimConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seed: int = 0,
    syn: Any | None = None,
    stimulus: Any | None = None,
    probes: Any | None = None,
    chunk_steps: Optional[int] = None,
    checkpoint_dir: Optional[str] = None,
    resume: bool = False,
    async_checkpoint: bool = False,
) -> SimResult:
    """Run `t_steps` of the network; returns per-neuron spike counts (the
    paper's validation statistic) plus any probe records.

    ``cfg.engine`` selects a registered delivery engine (see
    :func:`repro.core.engines.available_engines`); ``syn`` optionally
    supplies a prebuilt state from :func:`build_synapses`.  ``stimulus``
    is any :class:`repro.exp.Stimulus` (default: the legacy sugar-Poisson
    + background drive reconstructed from ``cfg`` and ``sugar_neurons``);
    ``probes`` is a :class:`repro.exp.ProbeSpec` (default: raster iff
    ``cfg.collect_raster``).

    ``chunk_steps=K`` runs the same simulation as ceil(T/K) reuses of one
    compiled K-step program with the carry threaded host-side — the
    result is bit-identical to the monolithic scan, but the host gets a
    supervision point every K steps where ``cfg.health`` thresholds are
    checked and (with ``checkpoint_dir``) the carry is checkpointed, so a
    killed run restarted with ``resume=True`` reproduces the
    uninterrupted run bit-for-bit.  See :mod:`repro.core.health` and
    ``docs/resilience.md``.

    With a telemetry session active (:func:`repro.obs.telemetry`), the
    run emits phase spans, per-chunk JSONL events, and compile-cache
    metrics (surfaced on ``SimResult.stats["compile_cache"]``) — all
    host-side, results bit-identical to an uninstrumented run; see
    ``docs/observability.md``.
    """
    tele = obs.active()
    with obs.span("simulate", engine=cfg.engine):
        n = c.n
        if syn is None:
            syn = build_synapses(c, cfg)
        stimulus = _resolve_stimulus(cfg, n, sugar_neurons, stimulus)
        probes = _resolve_probes(cfg, probes)
        carry = _init_carry(n, cfg, stimulus, seed)
        if tele is not None:
            tele.emit("run_start", kind="simulate", engine=cfg.engine,
                      n=n, t_steps=t_steps, chunk_steps=chunk_steps,
                      fixed_point=cfg.fixed_point)
        t_run = time.monotonic()
        # telemetry routes through the supervised chunk driver (one chunk
        # when chunk_steps is None) so the per-chunk event stream exists;
        # the chunked scan is bit-identical to the monolithic one
        if (chunk_steps is None and checkpoint_dir is None
                and cfg.health is None and tele is None):
            carry, records = _run_scan(syn, carry, stimulus, cfg, probes,
                                       t_steps, n)
        else:
            ckpt = (SimCheckpointer(checkpoint_dir,
                                    async_save=async_checkpoint)
                    if checkpoint_dir is not None else None)

            def run_chunk(cy, s, k):
                return _run_scan(syn, cy, stimulus, cfg, probes, k, n,
                                 jnp.int32(s))

            carry, records = run_chunked(
                run_chunk, carry, t_steps, chunk_steps, time_axis=0,
                health=cfg.health, n=n, dt_ms=cfg.params.dt,
                checkpointer=ckpt, resume=resume)
        stats = dict(carry.stats)
        if tele is not None:
            jax.block_until_ready(carry)
            tele.emit("run_end", steps=t_steps,
                      wall_s=round(time.monotonic() - t_run, 6),
                      counters=carry_counters(carry),
                      metrics=tele.metrics.counters())
            stats["compile_cache"] = tele.metrics.compile_snapshot()
    return SimResult(counts=carry.counts, state=carry.lif,
                     dropped=carry.dropped, raster=records.get("raster"),
                     records=records, stats=stats)


def spike_rates_hz(counts: jax.Array, t_steps: int, dt_ms: float) -> jax.Array:
    return counts.astype(jnp.float32) / (t_steps * dt_ms * 1e-3)


__all__ = ["SimConfig", "SimCarry", "SimResult", "available_engines",
           "build_synapses", "simulate", "spike_rates_hz"]
