"""Simulation engines for the connectome LIF network.

Four synaptic-delivery strategies, spanning the paper's comparison space:

* ``dense``  — g = W @ spikes.  The naive matmul the paper calls
  "computationally wasteful when the spiking activity is sparse".  Test-scale.
* ``csr``    — flat segment-sum over all synapses.  Cost ∝ nnz, independent
  of activity: the Brian2-like conventional baseline of Table 1.
* ``event``  — active-set event-driven delivery: compact spiking neurons to a
  fixed-capacity index list, ragged-gather their fan-out synapse ranges into
  a bounded synapse budget, scatter-add into targets.  Cost ∝ activity —
  the Loihi-like path whose speedup grows as activity sparsifies.
* ``binned`` — SAR bin-compressed delivery (per-bin active-source histogram ×
  unique weights).  Memory-compressed analogue of shared axon routing.

All engines share the LIF state machine (float or fixed-point) and a
ring-buffer implementation of the uniform 1.8 ms synaptic delay.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .compress import BinnedFormat, EllFormat, build_binned, build_ell, quantize_weights
from .connectome import Connectome
from .neuron import (LIFParams, LIFState, init_state, lif_step, lif_step_fx,
                     poisson_drive)


@dataclasses.dataclass(frozen=True)
class SimConfig:
    params: LIFParams = LIFParams()
    engine: str = "csr"             # dense | ell | csr | event | binned
    fixed_point: bool = False
    quantize_bits: Optional[int] = None   # 9 = Loihi; None = raw weights
    poisson_to_v: bool = True       # True = Brian2 semantics; False = Loihi approx
    poisson_rate_hz: float = 150.0
    poisson_weight: float = 180.0   # weight units delivered per Poisson event
    background_rate_hz: float = 0.0  # scaling-study probabilistic spiking
    spike_capacity: int = 512        # K: max active neurons per step (event)
    syn_budget: int = 65_536         # S_cap: max delivered synapses per step
    ell_width_cap: int = 4096        # SSD fan-in cap
    collect_raster: bool = False


class SynapseData(NamedTuple):
    """Device-resident synaptic state for every engine (unused fields empty)."""
    kind: str
    n: int
    # csr / event
    csr_src: jax.Array | None = None
    csr_tgt: jax.Array | None = None
    csr_w: jax.Array | None = None
    out_indptr: jax.Array | None = None
    out_tgt: jax.Array | None = None
    out_w: jax.Array | None = None
    # ell
    ell_idx: jax.Array | None = None
    ell_w: jax.Array | None = None
    # binned
    bin_src: jax.Array | None = None
    bin_id: jax.Array | None = None
    bin_weight: jax.Array | None = None
    n_bins: int = 0
    # dense
    w_dense: jax.Array | None = None


def build_synapses(c: Connectome, cfg: SimConfig) -> SynapseData:
    n = c.n
    w = c.in_weights
    if cfg.quantize_bits is not None:
        w = quantize_weights(w, cfg.quantize_bits)
    if cfg.engine == "dense":
        dense = np.zeros((n, n), np.float32)
        tgt = np.repeat(np.arange(n), c.fan_in)
        dense[tgt, c.in_indices] = w
        return SynapseData(kind="dense", n=n, w_dense=jnp.asarray(dense))
    if cfg.engine == "ell":
        ell: EllFormat = build_ell(c, cfg.ell_width_cap,
                                   quantize_bits=cfg.quantize_bits)
        return SynapseData(kind="ell", n=n, ell_idx=jnp.asarray(ell.idx),
                           ell_w=jnp.asarray(ell.weight))
    if cfg.engine == "csr":
        tgt = np.repeat(np.arange(n, dtype=np.int32), c.fan_in)
        return SynapseData(
            kind="csr", n=n,
            csr_src=jnp.asarray(c.in_indices),
            csr_tgt=jnp.asarray(tgt),
            csr_w=jnp.asarray(w.astype(np.float32)),
        )
    if cfg.engine == "event":
        ow = c.out_weights
        if cfg.quantize_bits is not None:
            ow = quantize_weights(ow, cfg.quantize_bits)
        return SynapseData(
            kind="event", n=n,
            out_indptr=jnp.asarray(c.out_indptr.astype(np.int32)),
            out_tgt=jnp.asarray(c.out_indices),
            out_w=jnp.asarray(ow.astype(np.float32)),
        )
    if cfg.engine == "binned":
        bf: BinnedFormat = build_binned(
            c, bits=cfg.quantize_bits if cfg.quantize_bits else 16)
        return SynapseData(
            kind="binned", n=n,
            bin_src=jnp.asarray(bf.src), bin_id=jnp.asarray(bf.bin_id),
            bin_weight=jnp.asarray(bf.bin_weight.astype(np.float32)),
            n_bins=bf.n_bins,
        )
    raise ValueError(cfg.engine)


# --------------------------------------------------------------------------
# Synaptic delivery (spikes[t-D] -> g_in in weight units)
# --------------------------------------------------------------------------

def deliver_dense(spk: jax.Array, syn: SynapseData) -> jax.Array:
    return syn.w_dense @ spk.astype(jnp.float32)


def deliver_ell(spk: jax.Array, syn: SynapseData) -> jax.Array:
    spk_pad = jnp.concatenate([spk.astype(jnp.float32), jnp.zeros((1,))])
    return (syn.ell_w * spk_pad[syn.ell_idx]).sum(axis=-1)


def deliver_csr(spk: jax.Array, syn: SynapseData) -> jax.Array:
    contrib = syn.csr_w * spk[syn.csr_src].astype(jnp.float32)
    return jax.ops.segment_sum(contrib, syn.csr_tgt, num_segments=syn.n)


def deliver_event(spk: jax.Array, syn: SynapseData, capacity: int,
                  syn_budget: int) -> tuple[jax.Array, jax.Array]:
    """Active-set event-driven delivery.  Returns (g_units, n_dropped)."""
    n = syn.n
    (act_idx,) = jnp.where(spk, size=capacity, fill_value=n)
    ai = jnp.minimum(act_idx, n - 1)
    valid_neuron = act_idx < n
    starts = jnp.where(valid_neuron, syn.out_indptr[ai], 0)
    fo = jnp.where(valid_neuron, syn.out_indptr[ai + 1] - syn.out_indptr[ai], 0)
    seg_end = jnp.cumsum(fo)
    total = seg_end[-1]
    slot = jnp.arange(syn_budget, dtype=jnp.int32)
    owner = jnp.searchsorted(seg_end, slot, side="right").astype(jnp.int32)
    owner_c = jnp.minimum(owner, capacity - 1)
    prev_end = jnp.where(owner_c > 0, seg_end[owner_c - 1], 0)
    within = slot - prev_end
    syn_ix = jnp.clip(starts[owner_c] + within, 0, syn.out_tgt.shape[0] - 1)
    valid = slot < jnp.minimum(total, syn_budget)
    contrib = jnp.where(valid, syn.out_w[syn_ix], 0.0)
    tgt = jnp.where(valid, syn.out_tgt[syn_ix], n)
    g = jax.ops.segment_sum(contrib, tgt, num_segments=n + 1)[:n]
    dropped = jnp.maximum(total - syn_budget, 0)
    return g, dropped


def deliver_binned(spk: jax.Array, syn: SynapseData) -> jax.Array:
    counts = jax.ops.segment_sum(
        spk[syn.bin_src].astype(jnp.float32), syn.bin_id,
        num_segments=syn.n * syn.n_bins)
    counts = counts.reshape(syn.n, syn.n_bins)
    return (syn.bin_weight * counts).sum(axis=-1)


def make_deliver(syn: SynapseData, cfg: SimConfig):
    if syn.kind == "dense":
        return lambda s: (deliver_dense(s, syn), jnp.int32(0))
    if syn.kind == "ell":
        return lambda s: (deliver_ell(s, syn), jnp.int32(0))
    if syn.kind == "csr":
        return lambda s: (deliver_csr(s, syn), jnp.int32(0))
    if syn.kind == "event":
        return lambda s: deliver_event(s, syn, cfg.spike_capacity, cfg.syn_budget)
    if syn.kind == "binned":
        return lambda s: (deliver_binned(s, syn), jnp.int32(0))
    raise ValueError(syn.kind)


# --------------------------------------------------------------------------
# Full simulation loop
# --------------------------------------------------------------------------

class SimCarry(NamedTuple):
    lif: LIFState
    ring: jax.Array        # [D, n] bool delayed-spike ring buffer
    ptr: jax.Array         # scalar int32
    key: jax.Array
    counts: jax.Array      # [n] int32 spike counts
    dropped: jax.Array     # scalar int32 total dropped synapse events


class SimResult(NamedTuple):
    counts: jax.Array
    state: LIFState
    dropped: jax.Array
    raster: jax.Array | None


def _one_step(carry: SimCarry, _, *, deliver, cfg: SimConfig,
              sugar_mask: jax.Array | None, n: int):
    p = cfg.params
    key, k_poisson, k_bg = jax.random.split(carry.key, 3)
    delayed = carry.ring[carry.ptr]
    g_units, drop = deliver(delayed)

    v_in = None
    force = None
    if sugar_mask is not None:
        draws = poisson_drive(k_poisson, n, cfg.poisson_rate_hz, p.dt, sugar_mask)
        if cfg.poisson_to_v:
            v_in = draws.astype(jnp.float32) * (p.v_th * 1.5)
        else:
            g_units = g_units + draws.astype(jnp.float32) * cfg.poisson_weight
    if cfg.background_rate_hz > 0:
        force = poisson_drive(k_bg, n, cfg.background_rate_hz, p.dt)

    if cfg.fixed_point:
        g_in = jnp.round(g_units).astype(jnp.int32)
        v_in_fx = (None if v_in is None
                   else jnp.round(v_in / p.w_scale).astype(jnp.int32))
        lif, spikes = lif_step_fx(carry.lif, g_in, p, v_in_fx, force)
    else:
        lif, spikes = lif_step(carry.lif, g_units * p.w_scale, p, v_in, force)

    ring = carry.ring.at[carry.ptr].set(spikes)
    ptr = (carry.ptr + 1) % cfg.params.delay_steps
    counts = carry.counts + spikes.astype(jnp.int32)
    new = SimCarry(lif=lif, ring=ring, ptr=ptr, key=key, counts=counts,
                   dropped=carry.dropped + drop.astype(jnp.int32))
    out = spikes if cfg.collect_raster else None
    return new, out


def simulate(
    c: Connectome,
    cfg: SimConfig,
    t_steps: int,
    sugar_neurons: np.ndarray | None = None,
    seed: int = 0,
    syn: SynapseData | None = None,
) -> SimResult:
    """Run `t_steps` of the network; returns per-neuron spike counts (the
    paper's validation statistic) and optionally the full raster."""
    n = c.n
    if syn is None:
        syn = build_synapses(c, cfg)
    deliver = make_deliver(syn, cfg)
    sugar_mask = None
    if sugar_neurons is not None:
        m = np.zeros(n, dtype=bool)
        m[sugar_neurons] = True
        sugar_mask = jnp.asarray(m)

    carry = SimCarry(
        lif=init_state(n, cfg.params, cfg.fixed_point),
        ring=jnp.zeros((cfg.params.delay_steps, n), dtype=bool),
        ptr=jnp.int32(0),
        key=jax.random.PRNGKey(seed),
        counts=jnp.zeros(n, jnp.int32),
        dropped=jnp.int32(0),
    )
    step = functools.partial(_one_step, deliver=deliver, cfg=cfg,
                             sugar_mask=sugar_mask, n=n)
    carry, raster = jax.lax.scan(step, carry, None, length=t_steps)
    return SimResult(counts=carry.counts, state=carry.lif,
                     dropped=carry.dropped, raster=raster)


def spike_rates_hz(counts: jax.Array, t_steps: int, dt_ms: float) -> jax.Array:
    return counts.astype(jnp.float32) / (t_steps * dt_ms * 1e-3)
