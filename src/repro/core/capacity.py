"""One capacity vocabulary for every event-driven path.

The static-shape budgets of the sparse paths — active-neuron slots,
delivered-synapse slots, active 128-block slots — used to live twice, as
three loose fields each on ``SimConfig`` and ``DistConfig`` with different
defaults.  :class:`CapacityConfig` is now the single carrier: the
monolithic ``event`` engine, every distributed exchange scheme, and
:func:`repro.core.engines.auto_capacity` all consume it.  The legacy
per-field knobs survive as deprecated constructor shims on both configs
(see :func:`merge_legacy_capacity`).
"""

from __future__ import annotations

import dataclasses
import math
import warnings


@dataclasses.dataclass(frozen=True)
class CapacityConfig:
    """Joint static-shape provisioning for the event-driven paths.

    ``spike_capacity`` (K) bounds active neurons per step (per partition on
    the distributed path), ``syn_budget`` (S_cap) bounds delivered synapses
    per step, ``block_capacity`` (B_cap) bounds active 128-blocks in the
    hierarchical compaction (0 = derive from K).  Budgets directly price
    the per-step O(B_cap·128 + S_cap) slot work; overruns are *counted*
    (``dropped``), never silent.
    """

    spike_capacity: int = 512
    syn_budget: int = 65_536
    block_capacity: int = 0

    def as_config_kwargs(self) -> dict:
        """Kwargs splat for ``SimConfig`` / ``DistConfig``:
        ``SimConfig(engine="event", **cap.as_config_kwargs())``."""
        return {"capacity": self}

    def _asdict(self) -> dict:    # NamedTuple-era compatibility
        return dataclasses.asdict(self)


#: Historical per-config defaults, preserved through the deprecation shims.
MONOLITHIC_CAPACITY = CapacityConfig()
DISTRIBUTED_CAPACITY = CapacityConfig(spike_capacity=256, syn_budget=32_768)


def merge_legacy_capacity(capacity: CapacityConfig | None,
                          spike_capacity: int | None,
                          syn_budget: int | None,
                          block_capacity: int | None,
                          default: CapacityConfig,
                          owner: str) -> CapacityConfig:
    """Resolve a config's capacity from the new field + the deprecated
    per-field shims.

    The deprecated fields warn only when they *change* the resolved value.
    The configs null the legacy fields out after merging (they are
    consumed into ``capacity``, the one read path), so
    ``dataclasses.replace(cfg, capacity=...)`` round-trips cleanly and a
    stale shim can never clobber an explicitly replaced capacity.
    """
    cap = capacity if capacity is not None else default
    legacy = {"spike_capacity": spike_capacity, "syn_budget": syn_budget,
              "block_capacity": block_capacity}
    changed = {k: v for k, v in legacy.items()
               if v is not None and v != getattr(cap, k)}
    if changed:
        # stacklevel: warn -> merge -> __post_init__ -> generated __init__
        # -> the caller's construction site
        warnings.warn(
            f"{owner}({', '.join(sorted(changed))}=...) is deprecated; pass "
            f"{owner}(capacity=CapacityConfig(...)) instead",
            DeprecationWarning, stacklevel=4)
        cap = dataclasses.replace(cap, **changed)
    return cap


def escalate_capacity(cap: CapacityConfig | None,
                      factor: float = 2.0) -> CapacityConfig | None:
    """Re-derive a larger :class:`CapacityConfig` after a drop-rate health
    breach (:mod:`repro.core.health`): every budget is scaled by
    ``factor``, so repeated escalations converge geometrically to a
    lossless provisioning while drops stay exactly counted along the way.
    ``None`` passes through (no base capacity to escalate — the
    supervisor then surfaces the breach instead of looping)."""
    if cap is None:
        return None
    if factor <= 1.0:
        raise ValueError(f"escalation factor must exceed 1, got {factor}")
    up = lambda x: int(math.ceil(x * factor))  # noqa: E731
    return CapacityConfig(
        spike_capacity=up(cap.spike_capacity),
        syn_budget=up(cap.syn_budget),
        block_capacity=up(cap.block_capacity) if cap.block_capacity else 0)


__all__ = ["CapacityConfig", "DISTRIBUTED_CAPACITY", "MONOLITHIC_CAPACITY",
           "escalate_capacity", "merge_legacy_capacity"]
