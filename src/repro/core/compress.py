"""Communication/memory compression schemes (paper §3.2.3).

Loihi 2 routes spikes through per-core *axon indexes*.  The paper exploits
this indirection two ways:

* **Shared synaptic delivery (SSD)** — one axon index per unique incoming
  source fans out to all of its local targets.  Spike volume: one message per
  (source, target-core) pair.  Memory: every synapse is still stored, so
  outlier fan-ins must be capped (paper: 4096, via sampling + weight rescale).

* **Shared axon routing (SAR)** — weights are quantized to 9 bits (capped to
  [-256, 255]) and synaptic memory is deduplicated per (target, unique
  weight): the axon index *is* a (target, weight) delivery, shared by every
  source with that effect.  Effective fan-in <= #unique weights (theoretical
  512, measured 165 vs raw 10,356).  Spike volume: full fan-out messages.

On TPU (see DESIGN.md §2) SAR becomes the **bin-compressed format**: per
target, <=B unique weights plus a flat synapse->bin membership map; synaptic
delivery = per-bin active-source histogram (segment_sum) followed by a tiny
dense dot with the bin weights.  SSD becomes the ELL row-capped format used
by the gather engines and the Pallas kernel.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .connectome import Connectome

WEIGHT_BITS = 9  # paper: 9-bit signed weights
W_CAP_LO = -(1 << (WEIGHT_BITS - 1))      # -256
W_CAP_HI = (1 << (WEIGHT_BITS - 1)) - 1   # 255


def quantize_weights(w: np.ndarray, bits: int = WEIGHT_BITS) -> np.ndarray:
    """Cap integer weights to the signed `bits`-bit range (paper §3.2.3)."""
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    return np.clip(w, lo, hi).astype(np.int32)


# --------------------------------------------------------------------------
# Effective fan statistics (paper Fig. 7)
# --------------------------------------------------------------------------

def effective_fan_in_sar(c: Connectome, bits: int = WEIGHT_BITS) -> np.ndarray:
    """Per-target number of unique quantized weights = SAR effective fan-in."""
    wq = quantize_weights(c.in_weights, bits)
    n = c.n
    eff = np.zeros(n, dtype=np.int64)
    # unique count per CSR row, vectorized: sort within rows then count steps
    row = np.repeat(np.arange(n), c.fan_in)
    order = np.lexsort((wq, row))
    row_s, w_s = row[order], wq[order]
    new_row = np.empty(len(row_s), dtype=bool)
    new_row[0:1] = True
    np.not_equal(row_s[1:], row_s[:-1], out=new_row[1:])
    new_val = np.empty(len(row_s), dtype=bool)
    new_val[0:1] = True
    np.not_equal(w_s[1:], w_s[:-1], out=new_val[1:])
    uniq = np.logical_or(new_row, new_val)
    np.add.at(eff, row_s, uniq.astype(np.int64))
    return eff


def effective_fan_out_ssd(c: Connectome, part_of_neuron: np.ndarray) -> np.ndarray:
    """Per-source number of distinct target partitions = SSD effective fan-out."""
    n = c.n
    src = np.repeat(np.arange(n), c.fan_out)
    tgt_part = part_of_neuron[c.out_indices]
    key = src * (part_of_neuron.max() + 2) + tgt_part
    uniq_keys = np.unique(key)
    eff = np.bincount((uniq_keys // (part_of_neuron.max() + 2)).astype(np.int64),
                      minlength=n)
    return eff


def compression_report(c: Connectome, part_of_neuron: np.ndarray | None = None,
                       bits: int = WEIGHT_BITS) -> dict:
    """Fig-7 style summary of both schemes."""
    eff_in = effective_fan_in_sar(c, bits)
    rep = {
        "raw_max_fan_in": int(c.fan_in.max()),
        "raw_max_fan_out": int(c.fan_out.max()),
        "sar_max_eff_fan_in": int(eff_in.max()),
        "sar_mean_eff_fan_in": float(eff_in.mean()),
        "sar_theoretical_max": 1 << bits,
        "sar_memory_ratio": float(eff_in.sum()) / max(1, c.nnz),
    }
    if part_of_neuron is not None:
        eff_out = effective_fan_out_ssd(c, part_of_neuron)
        rep.update({
            "ssd_max_eff_fan_out": int(eff_out.max()),
            "ssd_mean_eff_fan_out": float(eff_out.mean()),
            "ssd_message_ratio": float(eff_out.sum()) / max(1, c.nnz),
        })
    return rep


# --------------------------------------------------------------------------
# SSD: ELL row-capped target-major format (gather engines / Pallas kernel)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EllFormat:
    """Target-major padded ELL: idx/weight [n, width]; pad slots idx=n, w=0.

    ``scale`` carries the paper's fan-in-cap weight rescale: when a target's
    fan-in exceeds the cap we keep a uniform sample of `width` synapses and
    scale their weights by fan_in/width so the expected drive is preserved
    (paper §3.2.4: "limit the fan-in ... with a combination of sampling and
    weight rescaling").
    """

    idx: np.ndarray        # [n, width] int32, pad = n
    weight: np.ndarray     # [n, width] float32 (already rescaled; in weight units)
    width: int
    n_capped: int


def build_ell(c: Connectome, width_cap: int = 4096, quantize_bits: int | None = None,
              lane_multiple: int = 8, seed: int = 0) -> EllFormat:
    rng = np.random.default_rng(seed)
    w = c.in_weights
    if quantize_bits is not None:
        w = quantize_weights(w, quantize_bits)
    fan_in = c.fan_in
    width = int(min(width_cap, fan_in.max() if len(fan_in) else 1))
    width = max(lane_multiple, ((width + lane_multiple - 1) // lane_multiple)
                * lane_multiple)
    n = c.n
    idx = np.full((n, width), n, dtype=np.int32)
    wgt = np.zeros((n, width), dtype=np.float32)
    n_capped = 0
    starts = c.in_indptr[:-1]
    for t in range(n):
        f = int(fan_in[t])
        s = int(starts[t])
        if f <= width:
            idx[t, :f] = c.in_indices[s:s + f]
            wgt[t, :f] = w[s:s + f]
        else:
            n_capped += 1
            sel = rng.choice(f, width, replace=False)
            idx[t, :] = c.in_indices[s + sel]
            wgt[t, :] = w[s + sel] * (f / width)
    return EllFormat(idx=idx, weight=wgt, width=width, n_capped=n_capped)


# --------------------------------------------------------------------------
# SAR: bin-compressed format
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BinnedFormat:
    """SAR bin-compressed synaptic state.

    Per synapse (flat, target-major order): ``src`` [nnz] and ``bin_id`` [nnz]
    (global id = target * n_bins + local bin).  Per target: ``bin_weight``
    [n, n_bins] int32 (0 in pad bins).  Delivery:

        counts[t, b] = sum over synapses in bin (t,b) of spike[src]
        g_units[t]   = sum_b bin_weight[t, b] * counts[t, b]

    Memory: nnz int32 (membership) + n*n_bins weights — vs ELL's
    2*nnz-padded.  ``n_bins`` == max effective fan-in (paper: 165 at 9 bits).
    """

    src: np.ndarray         # [nnz] int32
    bin_id: np.ndarray      # [nnz] int32 global bin id
    bin_weight: np.ndarray  # [n, n_bins] int32
    n_bins: int

    @property
    def nnz(self) -> int:
        return int(self.src.shape[0])


def build_binned(c: Connectome, bits: int = WEIGHT_BITS,
                 lane_multiple: int = 8) -> BinnedFormat:
    wq = quantize_weights(c.in_weights, bits)
    n = c.n
    row = np.repeat(np.arange(n), c.fan_in)
    order = np.lexsort((wq, row))
    row_s, w_s, src_s = row[order], wq[order], c.in_indices[order]
    new_row = np.empty(len(row_s), dtype=bool)
    new_row[0:1] = True
    np.not_equal(row_s[1:], row_s[:-1], out=new_row[1:])
    new_val = np.empty(len(row_s), dtype=bool)
    new_val[0:1] = True
    np.not_equal(w_s[1:], w_s[:-1], out=new_val[1:])
    new_bin = np.logical_or(new_row, new_val)
    # local bin index within each target row
    bin_seq = np.cumsum(new_bin) - 1                       # global running bin
    row_first_bin = np.zeros(n, dtype=np.int64)
    first_pos = np.flatnonzero(new_row)
    row_first_bin[row_s[first_pos]] = bin_seq[first_pos]
    local_bin = bin_seq - row_first_bin[row_s]
    n_bins = int(local_bin.max()) + 1 if len(local_bin) else 1
    n_bins = max(lane_multiple,
                 ((n_bins + lane_multiple - 1) // lane_multiple) * lane_multiple)
    bin_weight = np.zeros((n, n_bins), dtype=np.int32)
    bin_weight[row_s[new_bin], local_bin[new_bin]] = w_s[new_bin]
    return BinnedFormat(
        src=src_s.astype(np.int32),
        bin_id=(row_s * n_bins + local_bin).astype(np.int32),
        bin_weight=bin_weight,
        n_bins=n_bins,
    )


# --------------------------------------------------------------------------
# Loihi-2 / TPU memory models (paper Figs 8-10 reproduction)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CoreBudget:
    """Per-core capacity model used by the greedy partitioner.

    Loihi preset reproduces the paper's binding constraints: 128 KB synaptic
    memory, a max axon-program size (the constraint that left SAR cores
    underutilized), and a spike-buffer reserve (the SSD adjustment).
    TPU preset models a VMEM-resident partition working set.
    """

    syn_mem_bytes: int
    bytes_per_syn: int = 4       # 9b weight + delay + dendrite idx, padded
    bytes_per_axon: int = 4
    max_axon_entries: int = 32_768   # axon-program size limit (sender side)
    spike_buffer_reserve: float = 0.20  # fraction of syn mem kept free (SSD)
    max_neurons: int = 1024

    @staticmethod
    def loihi2() -> "CoreBudget":
        return CoreBudget(syn_mem_bytes=128 * 1024)

    @staticmethod
    def tpu_vmem(vmem_bytes: int = 16 * 2**20, frac: float = 0.5) -> "CoreBudget":
        return CoreBudget(syn_mem_bytes=int(vmem_bytes * frac),
                          max_axon_entries=1 << 30,  # no axon-program analogue
                          spike_buffer_reserve=0.0,
                          max_neurons=1 << 20)


def core_memory_ssd(fan_in_capped: np.ndarray, eff_fan_out: np.ndarray,
                    budget: CoreBudget) -> dict:
    """Bytes used on one core holding targets with `fan_in_capped` and
    sources with `eff_fan_out` (SSD: one axon entry per target core)."""
    syn = int(fan_in_capped.sum()) * budget.bytes_per_syn
    axon = int(eff_fan_out.sum()) * budget.bytes_per_axon
    return {"syn_bytes": syn, "axon_entries": int(eff_fan_out.sum()),
            "total_bytes": syn + axon}


def core_memory_sar(eff_fan_in: np.ndarray, fan_out: np.ndarray,
                    budget: CoreBudget) -> dict:
    """SAR: synaptic memory stores unique (target, weight) entries; the
    sender-side axon program stores one entry per synapse (full fan-out)."""
    syn = int(eff_fan_in.sum()) * budget.bytes_per_syn
    axon_entries = int(fan_out.sum())
    return {"syn_bytes": syn, "axon_entries": axon_entries,
            "total_bytes": syn + axon_entries * budget.bytes_per_axon}
