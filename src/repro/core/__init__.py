"""Core library: the paper's contribution (connectome -> distributed
event-driven simulation with compression-aware partitioning).

Stimulation and observability are supplied by the :mod:`repro.exp` layer
above this one (stimulus protocols, probes, trial batches, scenarios);
the simulation loop here only exposes the hooks.
"""

from .connectome import (Connectome, cache_path, from_edges,
                         load_flywire_parquet, synthetic_flywire,
                         synthetic_flywire_cached)
from .neuron import (FLYWIRE_LIF, FLYWIRE_LIF_1MS, LIFParams, LIFState,
                     init_state, lif_step, lif_step_fx)
from .compress import (BinnedFormat, CoreBudget, EllFormat, build_binned,
                       build_ell, compression_report, effective_fan_in_sar,
                       effective_fan_out_ssd, quantize_weights)
from .partition import (PartitionCaps, Partitioning, caps_from_budget,
                        even_partition, greedy_partition, partition_report)
from .compaction import (active_fanout_total, derived_block_capacity,
                         ragged_slots, slot_owner, two_level_active)
from .capacity import CapacityConfig, escalate_capacity
from .engine import (SimCarry, SimConfig, SimResult, build_synapses,
                     simulate, spike_rates_hz)
from .engines import (Capacity, DeliveryEngine, auto_capacity,
                      available_engines, get_engine, register)
from .exchange import (ExchangeFault, ExchangeScheme, FaultSpec,
                       available_schemes, configure_faulty, get_scheme,
                       register_scheme)
from .health import (BackoffPolicy, HealthConfig, SimCheckpointer,
                     SimulationHealthError, run_chunked, run_resilient)
from .validate import (ParityStats, mean_rates_over_trials, parity,
                       raster_to_times)

__all__ = [k for k in dir() if not k.startswith("_")]
