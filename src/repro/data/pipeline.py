"""Deterministic, stateless synthetic LM data pipeline.

Batches are a pure function of (seed, step) — the property that makes
checkpoint/restart and elastic rescaling exact: a restarted (or re-meshed)
job regenerates precisely the batches it would have seen, with no data
state to checkpoint.  Each host builds only its addressable shards
(jax.make_array_from_callback), so the pipeline is host-sharded at any
scale.

The token stream is a order-2 Markov chain over the vocab (deterministic
transition mixing) rather than iid noise, so models have actual structure
to fit in the end-to-end example.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


def _tokens_for_slice(seed, step, lo, hi, seq, vocab):
    """[hi-lo, seq+1] deterministic tokens for global rows [lo, hi)."""
    rows = []
    for r in range(lo, hi):
        rng = np.random.default_rng(
            np.random.SeedSequence([seed, step, r]))
        x = np.empty(seq + 1, dtype=np.int64)
        x[0] = rng.integers(vocab)
        noise = rng.integers(0, vocab, size=seq)
        pure = rng.random(seq) < 0.25
        for t in range(seq):
            nxt = (x[t] * 48271 + 13) % vocab       # markov backbone
            x[t + 1] = noise[t] if pure[t] else nxt
        rows.append(x)
    return np.stack(rows).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class SyntheticLM:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0

    def batch_at(self, step: int, sharding=None):
        """Global [B, seq] tokens + labels, optionally sharded."""
        B, S = self.global_batch, self.seq
        shape = (B, S + 1)

        def cb(index):
            lo = index[0].start or 0
            hi = index[0].stop if index[0].stop is not None else B
            return _tokens_for_slice(self.seed, step, lo, hi, S, self.vocab)

        if sharding is not None:
            full = jax.make_array_from_callback(shape, sharding, cb)
        else:
            full = jnp.asarray(cb((slice(0, B), slice(None))))
        return {"tokens": full[:, :-1], "labels": full[:, 1:]}


def make_global_batch(cfg, shape_cell: dict, step: int, seed=0,
                      sharding=None):
    ds = SyntheticLM(vocab=cfg.vocab, seq=shape_cell["seq"],
                     global_batch=shape_cell["batch"], seed=seed)
    return ds.batch_at(step, sharding)
