from .model import (ModelConfig, abstract_params, count_params, decode_step,
                    forward, init_cache, init_params, loss_fn, param_axes,
                    param_specs, prefill)

__all__ = ["ModelConfig", "abstract_params", "count_params", "decode_step",
           "forward", "init_cache", "init_params", "loss_fn", "param_axes",
           "param_specs", "prefill"]
