"""Block assembly: heterogeneous layer patterns under a single lax.scan.

An architecture declares ``block_pattern`` — a tuple of mixer kinds cycled
over the depth, e.g. ("attn",) for dense, ("local",)*5 + ("global",) for
gemma3, ("rglru", "rglru", "local") for recurrentgemma, ("rwkv",) for
rwkv6.  Layers are grouped into n_repeats = L // len(pattern) scan steps
(each step applies one full pattern instance, params stacked on a leading
"layers" axis) plus an unrolled tail of L %% len(pattern) layers — so HLO
size stays O(len(pattern)) regardless of depth, which keeps the 80
dry-run compiles tractable.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.parallel.act import shard_act

from . import rglru as rg
from . import rwkv6 as rw
from .layers import (attention_apply, attention_decode, attention_init,
                     layer_norm, layer_norm_init, mlp_apply, mlp_init,
                     moe_apply, moe_init, rms_norm, rms_norm_init)
from .param import stack_layer_params


# --------------------------------------------------------------------------
# One block = mixer + ffn with pre-norm residuals
# --------------------------------------------------------------------------

def block_init(key, kind, cfg):
    km, kf, kn = jax.random.split(key, 3)
    norm_init = rms_norm_init if cfg.norm == "rms" else layer_norm_init
    p = {"norm1": norm_init(cfg.d_model), "norm2": norm_init(cfg.d_model)}
    if kind in ("attn", "local"):
        p["mixer"] = attention_init(km, cfg.d_model, cfg.n_heads,
                                    cfg.n_kv_heads, cfg.d_head, cfg.qkv_bias)
    elif kind == "rglru":
        p["mixer"] = rg.rglru_init(km, cfg.d_model, cfg.d_rnn)
    elif kind == "rwkv":
        p["mixer"] = rw.timemix_init(km, cfg.d_model)
    else:
        raise ValueError(kind)
    if cfg.n_experts > 0:
        p["ffn"] = moe_init(kf, cfg.d_model, cfg.d_ff, cfg.n_experts,
                            gated=True, shared_expert=cfg.shared_expert)
    elif kind == "rwkv":
        p["ffn"] = rw.chanmix_init(kf, cfg.d_model, cfg.d_ff)
    else:
        p["ffn"] = mlp_init(kf, cfg.d_model, cfg.d_ff, gated=cfg.gated_mlp)
    return p


def _norm(cfg):
    return rms_norm if cfg.norm == "rms" else layer_norm


def _pad_kv(kv, max_len):
    """[B, Hkv, S, D] -> [B, Hkv, max_len, D]."""
    S = kv.shape[2]
    if S == max_len:
        return kv
    return jnp.pad(kv, ((0, 0), (0, 0), (0, max_len - S), (0, 0)))


def block_apply(p, kind, x, cfg, *, causal=True, impl=None, max_len=None):
    """Full-sequence apply.  Returns (x, cache, aux_loss).

    ``max_len`` (prefill): build the block's decode cache, padded to
    max_len for attention kinds.  None (train): cache is None."""
    norm = _norm(cfg)
    impl = impl or cfg.attention_impl
    aux = jnp.float32(0.0)
    cache = None
    x = shard_act(x, "residual")
    h = norm(p["norm1"], x)
    if kind in ("attn", "local"):
        win = cfg.window if kind == "local" else None
        m, (kh, vh) = attention_apply(p["mixer"], h, cfg, causal=causal,
                                      window=win, impl=impl,
                                      use_rope=cfg.use_rope)
        if max_len is not None:
            cache = {"k": _pad_kv(kh, max_len), "v": _pad_kv(vh, max_len)}
    elif kind == "rglru":
        m, (hlast, a_tail) = rg.rglru_apply(p["mixer"], h,
                                            assoc=cfg.assoc_scan)
        if max_len is not None:
            cache = {"h": hlast, "tail": a_tail}
    elif kind == "rwkv":
        m, (shift_t, wkv) = rw.timemix_apply(p["mixer"], h)
    else:
        raise ValueError(kind)
    x = x + m
    h = norm(p["norm2"], x)
    if cfg.n_experts > 0:
        f, aux = moe_apply(p["ffn"], h, top_k=cfg.top_k,
                           capacity_factor=cfg.moe_capacity_factor,
                           act=cfg.act)
    elif kind == "rwkv":
        f, shift_c = rw.chanmix_apply(p["ffn"], h)
        if max_len is not None:
            cache = {"shift_t": shift_t, "wkv": wkv, "shift_c": shift_c}
    else:
        f = mlp_apply(p["ffn"], h, act=cfg.act)
    return shard_act(x + f, "residual"), cache, aux


def block_decode(p, kind, x, cfg, cache, pos):
    """One-token apply.  cache is the block's decode state."""
    norm = _norm(cfg)
    aux = jnp.float32(0.0)
    x = shard_act(x, "residual")
    h = norm(p["norm1"], x)
    if kind in ("attn", "local"):
        win = cfg.window if kind == "local" else None
        m, ck, cv = attention_decode(p["mixer"], h, cache["k"], cache["v"],
                                     pos, cfg, window=win,
                                     use_rope=cfg.use_rope)
        cache = {"k": ck, "v": cv}
    elif kind == "rglru":
        m, st = rg.rglru_decode(p["mixer"], h, (cache["h"], cache["tail"]))
        cache = {"h": st[0], "tail": st[1]}
    elif kind == "rwkv":
        m, st = rw.timemix_apply(p["mixer"], h, cache["shift_t"],
                                 cache["wkv"])
        cache = dict(cache, shift_t=st[0], wkv=st[1])
    else:
        raise ValueError(kind)
    x = x + m
    h = norm(p["norm2"], x)
    if cfg.n_experts > 0:
        f, aux = moe_apply(p["ffn"], h, top_k=cfg.top_k,
                           capacity_factor=cfg.moe_capacity_factor,
                           act=cfg.act)
    elif kind == "rwkv":
        f, sc = rw.chanmix_apply(p["ffn"], h, cache["shift_c"])
        cache = dict(cache, shift_c=sc)
    else:
        f = mlp_apply(p["ffn"], h, act=cfg.act)
    del aux
    return x + f, cache


def block_cache_init(kind, cfg, batch, max_len, dtype=jnp.float32):
    if kind in ("attn", "local"):
        shape = (batch, cfg.n_kv_heads, max_len, cfg.d_head)
        return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
    if kind == "rglru":
        return {"h": jnp.zeros((batch, cfg.d_rnn), dtype),
                "tail": jnp.zeros((batch, rg.CONV_W - 1, cfg.d_rnn), dtype)}
    if kind == "rwkv":
        H = cfg.d_model // rw.HEAD
        return {"shift_t": jnp.zeros((batch, cfg.d_model), dtype),
                "wkv": jnp.zeros((batch, H, rw.HEAD, rw.HEAD), jnp.float32),
                "shift_c": jnp.zeros((batch, cfg.d_model), dtype)}
    raise ValueError(kind)


# --------------------------------------------------------------------------
# Pattern-scan stack
# --------------------------------------------------------------------------

def stack_init(key, cfg):
    """Returns {"scan": tuple_per_pattern_pos(stacked over repeats),
    "tail": list of (kind, params)}."""
    pat = cfg.block_pattern
    n_rep, n_tail = cfg.n_layers // len(pat), cfg.n_layers % len(pat)
    keys = jax.random.split(key, cfg.n_layers + 1)
    scan_params = []
    ki = 0
    per_pos: list[list] = [[] for _ in pat]
    for r in range(n_rep):
        for j, kind in enumerate(pat):
            per_pos[j].append(block_init(keys[ki], kind, cfg))
            ki += 1
    scan_params = tuple(stack_layer_params(pp) if n_rep else None
                        for pp in per_pos)
    tail = []
    for j in range(n_tail):
        tail.append(block_init(keys[ki], pat[j], cfg))
        ki += 1
    return {"scan": scan_params, "tail": tuple(tail)}


def stack_apply(params, x, cfg, *, causal=True, impl=None):
    """Full-sequence forward through the pattern stack.  Returns (x, aux)."""
    pat = cfg.block_pattern
    n_rep = cfg.n_layers // len(pat)

    def body(carry, layer_params):
        h, aux = carry
        for j, kind in enumerate(pat):
            h, _, a = block_apply(layer_params[j], kind, h, cfg,
                                  causal=causal, impl=impl)
            aux = aux + a
        return (h, aux), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    aux = jnp.float32(0.0)
    if n_rep:
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), params["scan"])
    for j, p in enumerate(params["tail"]):
        x, _, a = block_apply(p, pat[j], x, cfg, causal=causal, impl=impl)
        aux = aux + a
    return x, aux


def stack_prefill(params, x, cfg, max_len, *, causal=True, impl=None):
    """Prefill: forward + per-layer decode caches.  Returns (x, caches)."""
    pat = cfg.block_pattern
    n_rep = cfg.n_layers // len(pat)

    def body(h, layer_params):
        caches = []
        for j, kind in enumerate(pat):
            h, ck, _ = block_apply(layer_params[j], kind, h, cfg,
                                   causal=causal, impl=impl, max_len=max_len)
            caches.append(ck)
        return h, tuple(caches)

    scan_caches = ()
    if n_rep:
        x, scan_caches = jax.lax.scan(body, x, params["scan"])
    tail_caches = []
    for j, p in enumerate(params["tail"]):
        x, ck, _ = block_apply(p, pat[j], x, cfg, causal=causal, impl=impl,
                               max_len=max_len)
        tail_caches.append(ck)
    return x, {"scan": scan_caches, "tail": tuple(tail_caches)}


def stack_cache_init(cfg, batch, max_len, dtype=jnp.float32):
    pat = cfg.block_pattern
    n_rep, n_tail = cfg.n_layers // len(pat), cfg.n_layers % len(pat)
    scan_caches = tuple(
        jax.tree.map(lambda a: jnp.broadcast_to(a, (n_rep,) + a.shape),
                     block_cache_init(kind, cfg, batch, max_len, dtype))
        for kind in pat) if n_rep else ()
    tail = tuple(block_cache_init(pat[j], cfg, batch, max_len, dtype)
                 for j in range(n_tail))
    return {"scan": scan_caches, "tail": tail}


def stack_decode(params, caches, x, cfg, pos):
    """One-token decode through the stack.  Returns (x, new_caches)."""
    pat = cfg.block_pattern
    n_rep = cfg.n_layers // len(pat)

    def body(h, xs):
        layer_params, layer_caches = xs
        new_caches = []
        for j, kind in enumerate(pat):
            h, ck = block_decode(layer_params[j], kind, h, cfg,
                                 layer_caches[j], pos)
            new_caches.append(ck)
        return h, tuple(new_caches)

    if n_rep:
        x, new_scan = jax.lax.scan(body, x, (params["scan"], caches["scan"]))
    else:
        new_scan = ()
    new_tail = []
    for j, p in enumerate(params["tail"]):
        x, ck = block_decode(p, pat[j], x, cfg, caches["tail"][j], pos)
        new_tail.append(ck)
    return x, {"scan": new_scan, "tail": tuple(new_tail)}
