"""Shared transformer layers: norms, RoPE, GQA attention (chunked-flash jnp
path for dry-run/CPU + Pallas path for TPU), SwiGLU/GeGLU MLP, sort-based
MoE.

Attention implementations:
  * "chunked" — lax.scan over kv blocks with online softmax (flash
    semantics in pure XLA: O(S·chunk) memory, FLOPs counted by
    cost_analysis).  Full S² score compute even under a causal mask.
  * "banded"  — unrolled static q-block loop where each q block only
    attends to its causal kv prefix (and/or local window): the S²/2 FLOP
    saving the Pallas kernel gets from block culling, expressed in XLA.
    Larger HLO; used as a perf-iteration variant.
  * "pallas"  — the flash_attention kernel (TPU runs).
All three share semantics with kernels/flash_attention/ref.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.act import shard_act

from .param import Param, bias_param, dense_param, scale_param

# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def rms_norm_init(d):
    return {"scale": scale_param(d, "embed")}


def rms_norm(p, x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * p["scale"]).astype(x.dtype)


def layer_norm_init(d):
    return {"scale": scale_param(d, "embed"), "bias": bias_param(d, "embed")}


def layer_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = xf.mean(-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x, positions, theta=1e4):
    """x: [..., S, n_heads, d_head]; positions: [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs      # [..., S, half]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

def attention_init(key, d_model, n_heads, n_kv_heads, d_head, qkv_bias=False):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_param(ks[0], d_model, n_heads * d_head, "embed", "heads"),
        "wk": dense_param(ks[1], d_model, n_kv_heads * d_head, "embed",
                          "kv_heads"),
        "wv": dense_param(ks[2], d_model, n_kv_heads * d_head, "embed",
                          "kv_heads"),
        "wo": dense_param(ks[3], n_heads * d_head, d_model, "heads", "embed"),
    }
    if qkv_bias:
        p["bq"] = bias_param(n_heads * d_head, "heads")
        p["bk"] = bias_param(n_kv_heads * d_head, "kv_heads")
        p["bv"] = bias_param(n_kv_heads * d_head, "kv_heads")
    return p


def _qkv(p, x, n_heads, n_kv_heads, d_head):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, n_heads, d_head)
    k = k.reshape(B, S, n_kv_heads, d_head)
    v = v.reshape(B, S, n_kv_heads, d_head)
    return q, k, v


def _expand_kv(k, groups):
    if groups == 1:
        return k
    return jnp.repeat(k, groups, axis=1)


def chunked_attention(q, k, v, *, causal, window, chunk=512, q_offset=0):
    """Online-softmax scan over kv chunks, GQA-grouped (KV is never
    expanded to H heads).  q: [B,H,Sq,D], k/v: [B,Hkv,Skv,D] with
    H %% Hkv == 0.  q position i attends to kv position j iff
    j <= i+q_offset (causal) and j > i+q_offset-window-1 (window)."""
    B, H, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    G = H // Hkv
    chunk = min(chunk, Skv)
    pad = (-Skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (Skv + pad) // chunk
    kc = jnp.moveaxis(k.reshape(B, Hkv, nc, chunk, D), 2, 0)
    vc = jnp.moveaxis(v.reshape(B, Hkv, nc, chunk, D), 2, 0)
    scale = D ** -0.5
    qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, G, Sq, D)
    q_ids = jnp.arange(Sq) + q_offset

    def step(carry, xs):
        m, l, acc = carry
        kb, vb, c0 = xs
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb.astype(jnp.float32))
        k_ids = c0 + jnp.arange(chunk)
        mask = k_ids[None, :] < Skv
        if causal:
            mask = mask & (k_ids[None, :] <= q_ids[:, None])
        if window is not None:
            mask = mask & (k_ids[None, :] > q_ids[:, None] - window - 1)
        s = jnp.where(mask[None, None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, G, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, G, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, G, Sq, D), jnp.float32)
    c0s = jnp.arange(nc) * chunk
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (kc, vc, c0s))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, H, Sq, D).astype(q.dtype)


def windowed_attention(q, k, v, *, window, block=1024):
    """Sliding-window attention as a *scan over q blocks*, each attending
    to a dynamically-sliced kv band of static size (window + block).

    Exact-window FLOPs like `banded_attention`, but scan-form: HLO stays
    O(1) in sequence length (no 32-block unroll) and the kv slice is a
    single dynamic-slice per step instead of per-block gathers — the fix
    for the resharding storm the unrolled form triggered on the 256-chip
    mesh."""
    B, H, S, D = q.shape
    Hkv = k.shape[1]
    G = H // Hkv
    block = min(block, S)
    pad_q = (-S) % block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    nq = (S + pad_q) // block
    band = min(S, ((window + block + block - 1) // block) * block)
    # pad kv front (band) and back (q padding) so every slice is in range
    k = jnp.pad(k, ((0, 0), (0, 0), (band, pad_q), (0, 0)))
    v = jnp.pad(v, ((0, 0), (0, 0), (band, pad_q), (0, 0)))
    qb = jnp.moveaxis(q.reshape(B, H, nq, block, D), 2, 0)
    scale = D ** -0.5

    def step(_, xs):
        qi, i = xs
        q0 = i * block
        k0 = q0 + block - band + band      # band ends at q-block end (+pad)
        kb = jax.lax.dynamic_slice_in_dim(k, k0, band, axis=2)
        vb = jax.lax.dynamic_slice_in_dim(v, k0, band, axis=2)
        qg = (qi.astype(jnp.float32) * scale).reshape(B, Hkv, G, block, D)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb.astype(jnp.float32))
        q_ids = q0 + jnp.arange(block)[:, None]
        k_ids = (q0 + block - band) + jnp.arange(band)[None, :]
        mask = (k_ids <= q_ids) & (k_ids > q_ids - window - 1) & (k_ids >= 0)
        mask &= k_ids < S
        s = jnp.where(mask[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        p = jnp.where(mask[None, None, None], p, 0.0)
        o = jnp.einsum("bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return None, o.reshape(B, H, block, D)

    _, ob = jax.lax.scan(step, None, (qb, jnp.arange(nq)))
    out = jnp.moveaxis(ob, 0, 2).reshape(B, H, nq * block, D)[:, :, :S]
    return out.astype(q.dtype)


def banded_attention(q, k, v, *, causal, window, block=1024):
    """Causal-prefix q-block loop: q block i only touches kv[: (i+1)*block]
    (or its window band) — S²/2 FLOPs instead of S². Static unroll."""
    B, H, S, D = q.shape
    block = min(block, S)
    nb = (S + block - 1) // block
    outs = []
    for i in range(nb):
        q0, q1 = i * block, min((i + 1) * block, S)
        qi = q[:, :, q0:q1]
        if window is not None:
            k0 = max(0, q0 - window)
        else:
            k0 = 0
        k1 = q1 if causal else S
        outs.append(chunked_attention(
            qi, k[:, :, k0:k1], v[:, :, k0:k1], causal=causal, window=window,
            chunk=block, q_offset=q0 - k0))
    return jnp.concatenate(outs, axis=2)


def attention_apply(p, x, cfg, *, causal=True, window=None, positions=None,
                    impl="chunked", use_rope=True):
    """Full-sequence (train / prefill) attention.  Returns (out, (k, v))."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    if positions is None:
        positions = jnp.arange(S)[None, :]
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    qh = jnp.moveaxis(q, 2, 1)     # [B, H, S, D]
    kh = jnp.moveaxis(k, 2, 1)     # [B, Hkv, S, D] — never GQA-expanded
    vh = jnp.moveaxis(v, 2, 1)
    if impl == "chunked":
        out = chunked_attention(qh, kh, vh, causal=causal, window=window)
    elif impl == "windowed" and window is not None and causal:
        out = windowed_attention(qh, kh, vh, window=window)
    elif impl == "windowed":
        out = chunked_attention(qh, kh, vh, causal=causal, window=window)
    elif impl == "banded":
        out = banded_attention(qh, kh, vh, causal=causal, window=window)
    elif impl == "pallas":
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(qh, kh, vh, causal=causal, window=window)
    else:
        raise ValueError(impl)
    out = jnp.moveaxis(out, 1, 2).reshape(B, S, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], (kh, vh)


def attention_decode(p, x, cache_k, cache_v, pos, cfg, *, window=None,
                     use_rope=True):
    """One-token decode.  x: [B, 1, d]; cache_k/v: [B, Hkv, Smax, D];
    pos: scalar OR per-slot [B] positions (continuous batching).
    Returns (out, cache_k, cache_v)."""
    B = x.shape[0]
    q, k, v = _qkv(p, x, cfg.n_heads, cfg.n_kv_heads, cfg.d_head)
    pos_b = jnp.broadcast_to(jnp.asarray(pos), (B,))
    if use_rope:
        q = rope(q, pos_b[:, None], cfg.rope_theta)
        k = rope(k, pos_b[:, None], cfg.rope_theta)
    qh = jnp.moveaxis(q, 2, 1)                        # [B, H, 1, D]
    kh = jnp.moveaxis(k, 2, 1)                        # [B, Hkv, 1, D]
    vh = jnp.moveaxis(v, 2, 1)
    bidx = jnp.arange(B)
    cache_k = cache_k.at[bidx, :, pos_b].set(kh[:, :, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[bidx, :, pos_b].set(vh[:, :, 0].astype(cache_v.dtype))
    groups = cfg.n_heads // cfg.n_kv_heads
    Smax = cache_k.shape[2]
    scale = cfg.d_head ** -0.5
    # grouped-query einsum: never materialize the G-times-repeated KV;
    # bf16 operands with f32 accumulation (casting the cache to f32 would
    # materialize a 2x-sized copy of the whole cache)
    qg = (qh * scale).reshape(B, cfg.n_kv_heads, groups, cfg.d_head)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(cache_k.dtype), cache_k,
                   preferred_element_type=jnp.float32)
    ids = jnp.arange(Smax)
    mask = ids[None, :] <= pos_b[:, None]             # [B, Smax]
    if window is not None:
        mask = mask & (ids[None, :] > pos_b[:, None] - window - 1)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    pw = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", pw.astype(cache_v.dtype), cache_v,
                     preferred_element_type=jnp.float32)
    out = out.astype(x.dtype).reshape(B, 1, cfg.n_heads * cfg.d_head)
    return out @ p["wo"], cache_k, cache_v


# --------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# --------------------------------------------------------------------------

def mlp_init(key, d_model, d_ff, gated=True):
    ks = jax.random.split(key, 3)
    p = {"w_up": dense_param(ks[0], d_model, d_ff, "embed", "mlp"),
         "w_down": dense_param(ks[1], d_ff, d_model, "mlp", "embed")}
    if gated:
        p["w_gate"] = dense_param(ks[2], d_model, d_ff, "embed", "mlp")
    return p


def mlp_apply(p, x, act="silu"):
    up = x @ p["w_up"]
    if "w_gate" in p:
        gate = x @ p["w_gate"]
        h = (jax.nn.silu(gate) if act == "silu" else jax.nn.gelu(gate)) * up
    else:
        h = jax.nn.gelu(up) if act == "gelu" else jax.nn.silu(up)
    return h @ p["w_down"]


# --------------------------------------------------------------------------
# MoE (sort-based dispatch, capacity-bounded — Switch/MegaBlocks style)
# --------------------------------------------------------------------------

def moe_init(key, d_model, d_ff, n_experts, gated=True, shared_expert=False):
    ks = jax.random.split(key, 5)
    sc = 1.0 / jnp.sqrt(d_model)

    def ew(k, a, b, in_ax, out_ax):
        w = jax.random.normal(k, (n_experts, a, b), jnp.float32) * sc
        return Param(w, ("experts", in_ax, out_ax))

    p = {"router": dense_param(ks[0], d_model, n_experts, "embed", None),
         "w_up": ew(ks[1], d_model, d_ff, "embed", "mlp"),
         "w_down": ew(ks[2], d_ff, d_model, "mlp", "embed")}
    if gated:
        p["w_gate"] = ew(ks[3], d_model, d_ff, "embed", "mlp")
    if shared_expert:
        p["shared"] = mlp_init(ks[4], d_model, d_ff, gated=gated)
    return p


def moe_apply(p, x, *, top_k, capacity_factor=1.25, act="gelu"):
    """x: [B, S, d].  Per-example sort-based dispatch into [B, E, C, d]
    expert buffers (group-limited capacity, group = one example row).

    Grouping the dispatch by example keeps every argsort/scatter local to
    the data shard that owns the example — a single global dispatch is
    unpartitionable for GSPMD and was observed to replicate 20 GiB expert
    buffers per device.  Per-group capacity is the standard Switch/GShard
    formulation.

    ``capacity_factor <= 0`` selects dropless dispatch (C = S * top_k, the
    worst-case bound): exact but memory-heavy — the setting smoke configs
    use so prefill/decode consistency is testable (single-token decode can
    never drop, so capacity drops in the full forward would show up as
    spurious cache mismatches).

    Returns (out, aux_loss).
    """
    B, S, d = x.shape
    E = p["w_up"].shape[0]
    logits = x @ p["router"]                              # [B, S, E]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gate_vals, choices = jax.lax.top_k(probs, top_k)      # [B, S, k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    if capacity_factor <= 0:
        C = S * top_k
    else:
        C = int(capacity_factor * S * top_k / E)
    C = max(8, ((C + 7) // 8) * 8)

    def dispatch_one(xe, ce, ge):
        """xe: [S, d]; ce/ge: [S, k] -> buffers + combine metadata."""
        flat_e = ce.reshape(-1)                           # [S*k]
        flat_t = jnp.repeat(jnp.arange(S), top_k)
        flat_g = ge.reshape(-1)
        order = jnp.argsort(flat_e)
        e_s, t_s, g_s = flat_e[order], flat_t[order], flat_g[order]
        idx = jnp.arange(S * top_k)
        first = jnp.searchsorted(e_s, jnp.arange(E))
        rank = idx - first[e_s]
        keep = rank < C
        slot = e_s * C + jnp.minimum(rank, C - 1)
        buf = jnp.zeros((E * C, d), xe.dtype)
        buf = buf.at[jnp.where(keep, slot, E * C - 1)].add(
            jnp.where(keep[:, None], xe[t_s], 0.0))
        return buf.reshape(E, C, d), (t_s, g_s, keep, slot)

    buf, meta = jax.vmap(dispatch_one)(x, choices, gate_vals)
    buf = shard_act(buf, "moe_buf")                       # [B, E, C, d]

    up = jnp.einsum("becd,edf->becf", buf, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("becd,edf->becf", buf, p["w_gate"])
        h = (jax.nn.gelu(gate) if act == "gelu" else jax.nn.silu(gate)) * up
    else:
        h = jax.nn.gelu(up)
    eo = jnp.einsum("becf,efd->becd", h, p["w_down"])
    eo = shard_act(eo, "moe_buf").reshape(B, E * C, d)

    def combine_one(eo_e, t_s, g_s, keep):
        slot_vals = eo_e * g_s[:, None].astype(eo_e.dtype)
        contrib = jnp.where(keep[:, None], slot_vals, 0.0)
        return jnp.zeros((S, d), eo_e.dtype).at[t_s].add(contrib)

    t_s, g_s, keep, slot = meta
    eo_g = jnp.take_along_axis(eo, slot[..., None], axis=1)
    out = jax.vmap(combine_one)(eo_g, t_s, g_s, keep)

    if "shared" in p:
        out = out + mlp_apply(p["shared"], x.reshape(B * S, d),
                              act=act).reshape(B, S, d)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean((0, 1))                               # [E]
    fe = jnp.zeros(E).at[choices.reshape(-1)].add(1.0) / (B * S * top_k)
    aux = E * jnp.sum(me * fe)
    return out, aux
