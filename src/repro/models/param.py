"""Parameter trees with logical sharding axes.

Leaves are plain jnp arrays; a parallel tree of *logical axis name tuples*
is built at init time and translated to mesh PartitionSpecs by
:mod:`repro.parallel.sharding`.  Logical names used across the stack:

  "embed"    — d_model            (usually replicated / FSDP over data)
  "heads"    — attention head dim (tensor-parallel over model)
  "kv_heads" — kv head dim
  "mlp"      — d_ff               (tensor-parallel over model)
  "vocab"    — vocabulary         (tensor-parallel over model)
  "experts"  — MoE expert dim     (expert-parallel over model)
  "layers"   — stacked-scan layer dim (never sharded)
  None       — replicated
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class Param:
    value: Any           # jnp array (or ShapeDtypeStruct in abstract init)
    axes: tuple          # logical axis names, len == ndim


jax.tree_util.register_pytree_node(
    Param,
    lambda p: ((p.value,), p.axes),
    lambda axes, kids: Param(kids[0], axes),
)


def is_param(x) -> bool:
    return isinstance(x, Param)


def split_tree(tree):
    """tree of Param -> (values tree, axes tree)."""
    vals = jax.tree.map(lambda p: p.value, tree, is_leaf=is_param)
    axes = jax.tree.map(lambda p: p.axes, tree, is_leaf=is_param)
    return vals, axes


def dense_param(key, in_dim, out_dim, in_ax, out_ax, dtype=jnp.float32,
                scale=None):
    scale = (1.0 / jnp.sqrt(in_dim)) if scale is None else scale
    w = jax.random.normal(key, (in_dim, out_dim), dtype) * scale
    return Param(w, (in_ax, out_ax))


def bias_param(dim, ax, dtype=jnp.float32):
    return Param(jnp.zeros((dim,), dtype), (ax,))


def scale_param(dim, ax, dtype=jnp.float32):
    return Param(jnp.ones((dim,), dtype), (ax,))


def stack_layer_params(per_layer: list):
    """List of identical Param trees -> one tree stacked on a new leading
    "layers" axis (for lax.scan over layers)."""
    def stack(*leaves):
        vals = jnp.stack([l.value for l in leaves])
        return Param(vals, ("layers",) + leaves[0].axes)
    return jax.tree.map(stack, *per_layer, is_leaf=is_param)
