"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block: x -> {a = conv1d(W_x x), b = gelu(W_y x)} -> RG-LRU(a) ⊙ b -> W_o.
RG-LRU:  r_t = σ(W_r a_t),  i_t = σ(W_i a_t),
         α_t = exp(-c · softplus(Λ) · r_t)          (c = 8)
         h_t = α_t ⊙ h_{t-1} + sqrt(1 - α_t²) ⊙ (i_t ⊙ a_t)

Train path scans over time; decode carries (h, conv tail) — O(1) state per
token, which is what makes the long_500k cell runnable for this arch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.act import shard_act

from .param import Param, bias_param, dense_param

CONV_W = 4
C_LRU = 8.0


def rglru_init(key, d_model, d_rnn):
    ks = jax.random.split(key, 6)
    lam = jnp.log(jnp.expm1(  # softplus^-1 so alpha in ~(0.9, 0.999)
        -jnp.log(jnp.linspace(0.9, 0.999, d_rnn)) / C_LRU))
    return {
        "w_x": dense_param(ks[0], d_model, d_rnn, "embed", "mlp"),
        "w_y": dense_param(ks[1], d_model, d_rnn, "embed", "mlp"),
        "conv": Param(jax.random.normal(ks[2], (CONV_W, d_rnn)) * 0.1,
                      (None, "mlp")),
        "w_r": dense_param(ks[3], d_rnn, d_rnn, "mlp", None),
        "w_i": dense_param(ks[4], d_rnn, d_rnn, "mlp", None),
        "lam": Param(lam, ("mlp",)),
        "w_o": dense_param(ks[5], d_rnn, d_model, "mlp", "embed"),
    }


def _lru_coeffs(p, a):
    """fp32 recurrence coefficients (Griffin runs the RG-LRU in fp32 for
    stability regardless of the activation dtype)."""
    a32 = a.astype(jnp.float32)
    r = jax.nn.sigmoid(a32 @ p["w_r"].astype(jnp.float32))
    i = jax.nn.sigmoid(a32 @ p["w_i"].astype(jnp.float32))
    log_alpha = -C_LRU * jax.nn.softplus(p["lam"]) * r
    alpha = jnp.exp(log_alpha)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_alpha), 1e-12))
    return alpha, beta * i * a32


def rglru_apply(p, x, h0=None, assoc=False):
    """x: [B, S, d].  Returns (out [B, S, d], (h_last, a_tail)) where
    a_tail = last CONV_W-1 pre-conv inputs (the decode conv window).

    assoc=True: the linear recurrence h_t = a_t*h + b_t runs as a
    log-depth associative scan over time — sequence-shardable (the carries
    exchanged between shards are [B, d_rnn], not [B, S, d_rnn]), the §Perf
    variant for the collective-bound prefill cells."""
    B, S, _ = x.shape
    a_in = x @ p["w_x"]
    b = jax.nn.gelu(x @ p["w_y"])
    # sequence sharding hook (no-op unless the launcher installs a policy)
    a_in = shard_act(a_in, "rglru_branch")
    b = shard_act(b, "rglru_branch")
    # depthwise causal conv, width 4
    a_pad = jnp.pad(a_in, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    a = sum(a_pad[:, i:i + S] * p["conv"][i] for i in range(CONV_W))
    alpha, drive = _lru_coeffs(p, a)

    if assoc:
        def combine(l, r):
            (al, bl), (ar, br) = l, r
            return al * ar, ar * bl + br

        if h0 is not None:
            drive = drive.at[:, 0].add(alpha[:, 0] * h0.astype(jnp.float32))
        _, hs = jax.lax.associative_scan(combine, (alpha, drive), axis=1)
        h = shard_act(hs.astype(x.dtype), "rglru_branch")
        a_tail = a_pad[:, S:S + CONV_W - 1]
        return (h * b) @ p["w_o"], (hs[:, -1].astype(x.dtype), a_tail)

    def chunk_step(h, xs):
        al, dr = xs                      # [C, B, d_rnn] chunks

        def step(hh, ys):
            a1, d1 = ys
            hh = a1 * hh + d1
            return hh, hh

        h, hs = jax.lax.scan(step, h, (al, dr))
        return h, hs

    d_rnn = a.shape[-1]
    h0 = (jnp.zeros((B, d_rnn), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))
    # chunked scan-of-remat: backward saves one state per chunk instead of
    # one per timestep (S x [B, d_rnn] fp32 would dominate training memory)
    C = min(64, S)
    pad_t = (-S) % C
    at = jnp.moveaxis(alpha, 1, 0)
    dt_ = jnp.moveaxis(drive, 1, 0)
    if pad_t:
        at = jnp.concatenate([at, jnp.ones((pad_t, B, d_rnn), at.dtype)])
        dt_ = jnp.concatenate([dt_, jnp.zeros((pad_t, B, d_rnn), dt_.dtype)])
    nch = (S + pad_t) // C
    at = at.reshape(nch, C, B, d_rnn)
    dt_ = dt_.reshape(nch, C, B, d_rnn)
    h_last, hs = jax.lax.scan(jax.checkpoint(chunk_step), h0, (at, dt_))
    h = jnp.moveaxis(hs.reshape(nch * C, B, d_rnn)[:S], 0, 1)
    h = h.astype(x.dtype)
    a_tail = a_pad[:, S:S + CONV_W - 1]    # last CONV_W-1 raw inputs
    return (h * b) @ p["w_o"], (h_last.astype(x.dtype), a_tail)


def rglru_decode(p, x, state):
    """x: [B, 1, d]; state = (h [B, d_rnn], conv_tail [B, CONV_W-1, d_rnn])."""
    h, tail = state
    a_t = (x @ p["w_x"])[:, 0]
    b_t = jax.nn.gelu(x @ p["w_y"])[:, 0]
    window = jnp.concatenate([tail, a_t[:, None]], axis=1)   # [B, 4, d_rnn]
    a = (window * p["conv"][None].astype(window.dtype)).sum(1)
    alpha, drive = _lru_coeffs(p, a)
    h_new = alpha * h.astype(jnp.float32) + drive
    out = ((h_new.astype(x.dtype) * b_t) @ p["w_o"])
    return out[:, None], (h_new.astype(h.dtype), window[:, 1:])


def rglru_init_state(batch, d_rnn, dtype=jnp.float32):
    return (jnp.zeros((batch, d_rnn), dtype),
            jnp.zeros((batch, CONV_W - 1, d_rnn), dtype))
