"""RWKV-6 "Finch" block (arXiv:2404.05892) — attention-free SSM with
data-dependent per-channel decay.

Time mixing (per head, head size 64):
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ
    y_t = (S_{t-1} + diag(u) k_t v_tᵀ)ᵀ r_t
with w_t = exp(-exp(w0 + LoRA_w(x̃))) the data-dependent decay (the Finch
contribution), and token-shift ddlerp mixes on every projection input.

Channel mixing: r ⊙ W_v(relu(W_k x̃)²).

Train path scans over time (state [B, H, 64, 64]); decode is O(1)/token —
this is why rwkv6-7b runs the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .param import Param, dense_param

HEAD = 64
LORA_R = 32


def _lora_init(key, d, r, out=None):
    out = d if out is None else out
    k1, k2 = jax.random.split(key)
    return {"a": Param(jax.random.normal(k1, (d, r)) * 0.01, ("embed", None)),
            "b": Param(jax.random.normal(k2, (r, out)) * 0.01, (None, "embed"))}


def _lora(p, x):
    return jnp.tanh(x @ p["a"]) @ p["b"]


def timemix_init(key, d):
    ks = jax.random.split(key, 12)
    mu = lambda i: Param(jnp.full((d,), 0.5), ("embed",))
    return {
        "mu_base": mu(0), "mu_w": mu(1), "mu_k": mu(2), "mu_v": mu(3),
        "mu_r": mu(4), "mu_g": mu(5),
        "lora_mix": _lora_init(ks[0], d, LORA_R, d * 5),
        "w0": Param(jnp.full((d,), -6.0), ("embed",)),
        "lora_w": _lora_init(ks[1], d, LORA_R),
        "u": Param(jnp.zeros((d,)), ("embed",)),
        "w_r": dense_param(ks[2], d, d, "embed", "heads"),
        "w_k": dense_param(ks[3], d, d, "embed", "heads"),
        "w_v": dense_param(ks[4], d, d, "embed", "heads"),
        "w_g": dense_param(ks[5], d, d, "embed", "heads"),
        "w_o": dense_param(ks[6], d, d, "heads", "embed"),
        "ln_scale": Param(jnp.ones((d,)), ("embed",)),
    }


def _ddlerp(p, x, x_prev):
    """Finch data-dependent token-shift: one fused LoRA producing the five
    per-projection mix deltas."""
    d = x.shape[-1]
    base = x + (x_prev - x) * p["mu_base"]
    deltas = _lora(p["lora_mix"], base).reshape(*x.shape[:-1], 5, d)
    mixes = []
    for i, name in enumerate(("mu_w", "mu_k", "mu_v", "mu_r", "mu_g")):
        m = p[name] + deltas[..., i, :]
        mixes.append(x + (x_prev - x) * m)
    return mixes


def _wkv_scan(r, k, v, w, u, state, chunk: int = 64):
    """r/k/v: [B, S, H, 64]; w: [B, S, H, 64] decay in (0,1); u: [H, 64].
    Returns (y [B, S, H, 64], state' [B, H, 64, 64]).

    Chunked scan-of-remat: the naive per-step scan saves the [B,H,64,64]
    state for every timestep in the backward pass (S x 1 MiB at 7B scale
    — dominates training memory); checkpointing per `chunk` steps keeps
    one state per chunk and recomputes inside."""
    def step(S, xs):
        rt, kt, vt, wt = xs                      # [B, H, 64] each
        kv = kt[..., :, None] * vt[..., None, :]           # [B,H,64,64]
        y = jnp.einsum("bhij,bhi->bhj", S + u[..., :, None] * kv, rt)
        S = wt[..., :, None] * S + kv
        return S, y

    def chunk_step(S, xs):
        return jax.lax.scan(step, S, xs)

    B, T = r.shape[0], r.shape[1]
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    chunk = min(chunk, T)
    pad = (-T) % chunk
    if pad:
        zpad = lambda t: jnp.concatenate(
            [t, jnp.zeros((pad,) + t.shape[1:], t.dtype)])
        r_, k_, v_ = (zpad(t) for t in xs[:3])
        w_ = jnp.concatenate([xs[3], jnp.ones((pad,) + xs[3].shape[1:],
                                              xs[3].dtype)])
        xs = (r_, k_, v_, w_)
    nch = (T + pad) // chunk
    xs = tuple(t.reshape(nch, chunk, *t.shape[1:]) for t in xs)
    state, ys = jax.lax.scan(jax.checkpoint(chunk_step), state, xs)
    ys = ys.reshape(nch * chunk, *ys.shape[2:])[:T]
    return jnp.moveaxis(ys, 0, 1), state


def timemix_apply(p, x, shift_state=None, wkv_state=None):
    """x: [B, S, d].  Returns (out, (x_last, wkv_state))."""
    B, S, d = x.shape
    H = d // HEAD
    if shift_state is None:
        shift_state = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)

    w = jnp.exp(-jnp.exp(p["w0"] + _lora(p["lora_w"], xw)))
    r = (xr @ p["w_r"]).reshape(B, S, H, HEAD)
    k = (xk @ p["w_k"]).reshape(B, S, H, HEAD)
    v = (xv @ p["w_v"]).reshape(B, S, H, HEAD)
    g = jax.nn.silu(xg @ p["w_g"])
    wh = w.reshape(B, S, H, HEAD)
    u = p["u"].reshape(H, HEAD)

    if wkv_state is None:
        wkv_state = jnp.zeros((B, H, HEAD, HEAD), jnp.float32)
    y, wkv_state = _wkv_scan(r, k, v, wh, u, wkv_state)
    y = y.reshape(B, S, d)
    # per-head group norm
    yh = y.reshape(B, S, H, HEAD).astype(jnp.float32)
    yh = (yh - yh.mean(-1, keepdims=True)) * jax.lax.rsqrt(
        yh.var(-1, keepdims=True) + 1e-5)
    y = (yh.reshape(B, S, d) * p["ln_scale"]).astype(x.dtype)
    out = (y * g) @ p["w_o"]
    return out, (x[:, -1], wkv_state)


def chanmix_init(key, d, d_ff):
    ks = jax.random.split(key, 3)
    return {
        "mu_k": Param(jnp.full((d,), 0.5), ("embed",)),
        "mu_r": Param(jnp.full((d,), 0.5), ("embed",)),
        "w_k": dense_param(ks[0], d, d_ff, "embed", "mlp"),
        "w_v": dense_param(ks[1], d_ff, d, "mlp", "embed"),
        "w_r": dense_param(ks[2], d, d, "embed", None),
    }


def chanmix_apply(p, x, shift_state=None):
    B, S, d = x.shape
    if shift_state is None:
        shift_state = jnp.zeros((B, d), x.dtype)
    x_prev = jnp.concatenate([shift_state[:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["mu_k"]
    xr = x + (x_prev - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["w_k"]))
    return jax.nn.sigmoid(xr @ p["w_r"]) * (k @ p["w_v"]), x[:, -1]
