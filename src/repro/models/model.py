"""Public model API: config, init, forward, loss, prefill/decode.

One code path serves all ten assigned architectures; family-specific
behaviour is driven entirely by ModelConfig (block_pattern, experts,
enc-dec, modality stubs).  Params are plain pytrees of arrays; logical
sharding axes are produced alongside by ``param_axes`` (no allocation —
eval_shape) and mapped to mesh PartitionSpecs in repro.parallel.sharding.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.parallel.act import shard_act

from .layers import (attention_decode, attention_init, attention_apply,
                     layer_norm, layer_norm_init, mlp_apply, mlp_init,
                     rms_norm, rms_norm_init)
from .param import Param, is_param, split_tree, stack_layer_params
from . import transformer as tf
from . import rwkv6 as rw


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                   # 0 -> d_model // n_heads
    block_pattern: tuple = ("attn",)
    window: Optional[int] = None      # local-attention window
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    moe_capacity_factor: float = 1.25  # 0 -> dropless (C = S * top_k)
    qkv_bias: bool = False
    norm: str = "rms"                 # rms | ln
    act: str = "silu"
    gated_mlp: bool = True
    use_rope: bool = True
    rope_theta: float = 1e4
    learned_pos: int = 0              # >0: learned absolute positions (whisper)
    tie_embeddings: bool = False
    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0
    dec_max: int = 0                  # decoder architectural max (whisper 448)
    # vlm (llava)
    n_patches: int = 0
    # hybrid (recurrentgemma)
    d_rnn: int = 0
    # execution knobs
    attention_impl: str = "chunked"   # chunked | banded | pallas
    assoc_scan: bool = False          # RG-LRU: log-depth associative scan
    remat: bool = True
    param_dtype: Any = jnp.float32
    compute_dtype: Any = None         # e.g. jnp.bfloat16: cast >=2D params
                                      # for compute (fuses with FSDP gather)
    # sub-quadratic? (drives long_500k cell eligibility)
    subquadratic: bool = False

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head",
                               self.d_model // max(1, self.n_heads))

    @property
    def is_encdec(self) -> bool:
        return self.n_enc_layers > 0


# --------------------------------------------------------------------------
# Whisper-style enc-dec decoder block (self-attn + cross-attn + mlp)
# --------------------------------------------------------------------------

def _encdec_block_init(key, cfg):
    ks = jax.random.split(key, 3)
    ninit = rms_norm_init if cfg.norm == "rms" else layer_norm_init
    return {
        "norm1": ninit(cfg.d_model), "norm2": ninit(cfg.d_model),
        "norm3": ninit(cfg.d_model),
        "self": attention_init(ks[0], cfg.d_model, cfg.n_heads,
                               cfg.n_kv_heads, cfg.d_head),
        "cross": attention_init(ks[1], cfg.d_model, cfg.n_heads,
                                cfg.n_kv_heads, cfg.d_head),
        "ffn": mlp_init(ks[2], cfg.d_model, cfg.d_ff, gated=False),
    }


def _cross_kv(p, enc_out, cfg):
    B, Se, _ = enc_out.shape
    k = (enc_out @ p["wk"]).reshape(B, Se, cfg.n_kv_heads, cfg.d_head)
    v = (enc_out @ p["wv"]).reshape(B, Se, cfg.n_kv_heads, cfg.d_head)
    return jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1)   # [B, Hkv, Se, D]


def _cross_attend(p, x, ck, cv, cfg):
    """x: [B, Sq, d]; ck/cv: [B, Hkv, Se, D] precomputed encoder kv."""
    B, Sq, _ = x.shape
    q = (x @ p["wq"]).reshape(B, Sq, cfg.n_heads, cfg.d_head)
    if "bq" in p:
        q = q + p["bq"].reshape(cfg.n_heads, cfg.d_head)
    qh = jnp.moveaxis(q, 2, 1)
    groups = cfg.n_heads // cfg.n_kv_heads
    kx = jnp.repeat(ck, groups, axis=1) if groups > 1 else ck
    vx = jnp.repeat(cv, groups, axis=1) if groups > 1 else cv
    s = jnp.einsum("bhqd,bhkd->bhqk", qh.astype(jnp.float32)
                   * cfg.d_head ** -0.5, kx.astype(jnp.float32))
    pw = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", pw, vx.astype(jnp.float32))
    o = jnp.moveaxis(o.astype(x.dtype), 1, 2).reshape(
        B, Sq, cfg.n_heads * cfg.d_head)
    return o @ p["wo"]


def _encdec_block_apply(p, x, enc_kv, cfg, *, pos=None, cache=None,
                        max_len=None):
    """Train (pos None, full seq) or decode (pos given).  With ``max_len``
    the full-seq path also returns the padded self-attn kv cache."""
    norm = rms_norm if cfg.norm == "rms" else layer_norm
    h = norm(p["norm1"], x)
    if pos is None:
        m, (kh, vh) = attention_apply(p["self"], h, cfg, causal=True,
                                      impl=cfg.attention_impl,
                                      use_rope=cfg.use_rope)
        if max_len is not None:
            cache = {"k": tf._pad_kv(kh, max_len),
                     "v": tf._pad_kv(vh, max_len)}
    else:
        m, ck, cv = attention_decode(p["self"], h, cache["k"], cache["v"],
                                     pos, cfg, use_rope=cfg.use_rope)
        cache = dict(cache, k=ck, v=cv)
    x = x + m
    h = norm(p["norm2"], x)
    x = x + _cross_attend(p["cross"], h, enc_kv[0], enc_kv[1], cfg)
    h = norm(p["norm3"], x)
    x = x + mlp_apply(p["ffn"], h, act="gelu")
    return x, cache


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 8)
    dt = cfg.param_dtype
    sc = 0.02
    p: dict = {}
    p["embed"] = Param(jax.random.normal(ks[0], (cfg.vocab, cfg.d_model),
                                         dt) * sc, ("vocab", "embed"))
    if not cfg.tie_embeddings:
        p["lm_head"] = Param(jax.random.normal(
            ks[1], (cfg.d_model, cfg.vocab), dt) * sc, ("embed", "vocab"))
    ninit = rms_norm_init if cfg.norm == "rms" else layer_norm_init
    p["final_norm"] = ninit(cfg.d_model)

    if cfg.is_encdec:
        enc_cfg = cfg
        p["enc_pos"] = Param(jax.random.normal(
            ks[2], (cfg.enc_seq, cfg.d_model), dt) * sc, (None, "embed"))
        p["dec_pos"] = Param(jax.random.normal(
            ks[3], (cfg.dec_max, cfg.d_model), dt) * sc, (None, "embed"))
        enc_keys = jax.random.split(ks[4], cfg.n_enc_layers)
        p["encoder"] = stack_layer_params(
            [tf.block_init(k, "attn", enc_cfg) for k in enc_keys])
        dec_keys = jax.random.split(ks[5], cfg.n_layers)
        p["decoder"] = stack_layer_params(
            [_encdec_block_init(k, cfg) for k in dec_keys])
        p["enc_norm"] = ninit(cfg.d_model)
    else:
        p["stack"] = tf.stack_init(ks[6], cfg)
    if cfg.learned_pos and not cfg.is_encdec:
        p["pos"] = Param(jax.random.normal(
            ks[7], (cfg.learned_pos, cfg.d_model), dt) * sc, (None, "embed"))
    return p


def init_params(key, cfg: ModelConfig):
    vals, _ = split_tree(_init(key, cfg))
    return vals


def param_axes(cfg: ModelConfig):
    tree = jax.eval_shape(functools.partial(_init, cfg=cfg),
                          jax.random.PRNGKey(0))
    _, axes = split_tree(tree)
    return axes


def abstract_params(cfg: ModelConfig):
    tree = jax.eval_shape(functools.partial(_init, cfg=cfg),
                          jax.random.PRNGKey(0))
    vals, _ = split_tree(tree)
    return vals


def param_specs(cfg: ModelConfig):
    """(abstract values, logical axes) — for the dry-run."""
    return abstract_params(cfg), param_axes(cfg)


def count_params(cfg: ModelConfig) -> int:
    import math
    vals = abstract_params(cfg)
    return sum(math.prod(v.shape) for v in jax.tree.leaves(vals))


# --------------------------------------------------------------------------
# forward / loss
# --------------------------------------------------------------------------

def _embed_inputs(params, batch, cfg):
    """Token (+modality-stub) embedding.  Returns [B, S, d]."""
    emb = params["embed"]
    x = emb[batch["tokens"]]
    if cfg.n_patches and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    if cfg.learned_pos and "pos" in params and not cfg.is_encdec:
        S = x.shape[1]
        x = x + params["pos"][:S]
    return x


def cast_for_compute(params, cfg: ModelConfig):
    """bf16-cast matrices for compute while fp32 masters live in the
    optimizer.  Casting *before* the FSDP all-gather halves the gather
    traffic (XLA fuses the convert into the collective)."""
    cd = cfg.compute_dtype
    if cd is None:
        return params
    return jax.tree.map(
        lambda p: p.astype(cd) if (hasattr(p, "ndim") and p.ndim >= 2)
        else p, params)


def forward(params, batch, cfg: ModelConfig):
    """Returns (logits [B, S, vocab], aux_loss)."""
    params = cast_for_compute(params, cfg)
    if cfg.is_encdec:
        frames = batch["frames"]                  # [B, enc_seq, d] stub
        enc = frames.astype(cfg.param_dtype) + params["enc_pos"][None]

        def enc_body(h, lp):
            h, _, _ = tf.block_apply(lp, "attn", h, cfg, causal=False,
                                     impl=cfg.attention_impl)
            return h, None
        enc_body = jax.checkpoint(enc_body) if cfg.remat else enc_body
        enc, _ = jax.lax.scan(enc_body, enc, params["encoder"])
        enc = (rms_norm if cfg.norm == "rms" else layer_norm)(
            params["enc_norm"], enc)

        x = params["embed"][batch["tokens"]]
        x = x + params["dec_pos"][:x.shape[1]][None]

        def dec_body(h, lp):
            kv = _cross_kv(lp["cross"], enc, cfg)
            h, _ = _encdec_block_apply(lp, h, kv, cfg)
            return h, None
        dec_body = jax.checkpoint(dec_body) if cfg.remat else dec_body
        x, _ = jax.lax.scan(dec_body, x, params["decoder"])
        aux = jnp.float32(0.0)
    else:
        x = shard_act(_embed_inputs(params, batch, cfg), "residual")
        x, aux = tf.stack_apply(params["stack"], x, cfg, causal=True)

    x = (rms_norm if cfg.norm == "rms" else layer_norm)(
        params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = shard_act(x @ head, "logits")
    return logits, aux


def loss_fn(params, batch, cfg: ModelConfig, aux_weight=0.01):
    """Next-token cross entropy (+ MoE aux).  batch["labels"]: [B, S]."""
    logits, aux = forward(params, batch, cfg)
    labels = batch["labels"]
    if cfg.n_patches and "patches" in batch:
        # patch positions carry no label loss
        logits = logits[:, cfg.n_patches:]
    V = logits.shape[-1]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    del V
    return nll + aux_weight * aux


# --------------------------------------------------------------------------
# serving: prefill + decode
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
               dtype=jnp.float32):
    if cfg.is_encdec:
        dec_max = cfg.dec_max
        self_kv = {"k": jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads,
                                   dec_max, cfg.d_head), dtype),
                   "v": jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads,
                                   dec_max, cfg.d_head), dtype)}
        cross_kv = {"k": jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads,
                                    cfg.enc_seq, cfg.d_head), dtype),
                    "v": jnp.zeros((cfg.n_layers, batch_size, cfg.n_kv_heads,
                                    cfg.enc_seq, cfg.d_head), dtype)}
        return {"self": self_kv, "cross": cross_kv}
    return tf.stack_cache_init(cfg, batch_size, max_len, dtype)


def prefill(params, batch, cfg: ModelConfig, max_len: int):
    """Returns (last-token logits, cache)."""
    params = cast_for_compute(params, cfg)
    if cfg.is_encdec:
        frames = batch["frames"]
        enc = frames.astype(cfg.param_dtype) + params["enc_pos"][None]

        def enc_body(h, lp):
            h, _, _ = tf.block_apply(lp, "attn", h, cfg, causal=False,
                                     impl=cfg.attention_impl)
            return h, None
        enc, _ = jax.lax.scan(enc_body, enc, params["encoder"])
        enc = (rms_norm if cfg.norm == "rms" else layer_norm)(
            params["enc_norm"], enc)

        x = params["embed"][batch["tokens"]]
        S = x.shape[1]
        x = x + params["dec_pos"][:S][None]

        def dec_body(h, lp):
            kv = _cross_kv(lp["cross"], enc, cfg)
            h, sc = _encdec_block_apply(lp, h, kv, cfg, max_len=cfg.dec_max)
            return h, {"self": sc, "cross": {"k": kv[0], "v": kv[1]}}
        x, kvs = jax.lax.scan(dec_body, x, params["decoder"])
        cache = {"self": kvs["self"], "cross": kvs["cross"]}
    else:
        x = shard_act(_embed_inputs(params, batch, cfg), "residual")
        x, cache = tf.stack_prefill(params["stack"], x, cfg, max_len,
                                    causal=True)
    x = (rms_norm if cfg.norm == "rms" else layer_norm)(
        params["final_norm"], x[:, -1:])
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x @ head)[:, 0], cache


def decode_step(params, cache, tokens, pos, cfg: ModelConfig):
    """tokens: [B] int32; pos: scalar or per-slot [B] int32 write position.
    Returns (logits [B, vocab], new cache)."""
    params = cast_for_compute(params, cfg)
    B = tokens.shape[0]
    x = params["embed"][tokens][:, None]           # [B, 1, d]
    if cfg.is_encdec:
        pe = jnp.take(params["dec_pos"], jnp.broadcast_to(pos, (B,)), axis=0)
        x = x + pe[:, None]

        def body(h, xs):
            lp, sc, cc = xs
            h, new_sc = _encdec_block_apply(
                lp, h, (cc["k"], cc["v"]), cfg, pos=pos, cache=sc)
            return h, new_sc
        x, new_self = jax.lax.scan(
            body, x, (params["decoder"], cache["self"], cache["cross"]))
        new_cache = {"self": new_self, "cross": cache["cross"]}
    else:
        if cfg.learned_pos and "pos" in params:
            pe = jnp.take(params["pos"], jnp.broadcast_to(pos, (B,)), axis=0)
            x = x + pe[:, None]
        x, new_cache = tf.stack_decode(params["stack"], cache, x, cfg, pos)
    x = (rms_norm if cfg.norm == "rms" else layer_norm)(
        params["final_norm"], x)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x @ head)[:, 0], new_cache
