"""Quickstart: build a FlyWire-statistics connectome, run the sugar-neuron
experiment across delivery engines, validate spike-rate parity (paper Fig 6).

    PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (SimConfig, available_engines, parity, simulate,
                        synthetic_flywire)
from repro.core.engine import spike_rates_hz
from repro.exp import PoissonDrive

# 1. a reduced connectome with the paper's degree/weight statistics
c = synthetic_flywire(n=5000, target_synapses=150_000, seed=0)
print("connectome:", c.stats())
print("registered delivery engines:", available_engines())

# 2. sugar-neuron experiment: 20 Poisson-driven inputs at 150 Hz
sugar = np.arange(20, dtype=np.int32)
T = 1000                      # 100 ms at dt=0.1ms

# conventional flat delivery (Brian2-like reference)
ref = simulate(c, SimConfig(engine="csr"), T, seed=1,
               stimulus=PoissonDrive(idx=sugar, rate_hz=150.0))
# event-driven delivery with 9-bit quantized weights + fixed-point LIF
# (the Loihi 2 hardware path): Poisson as synaptic drive, not membrane
hw = simulate(c, SimConfig(engine="event", quantize_bits=9,
                           fixed_point=True),
              T, seed=1,
              stimulus=PoissonDrive(idx=sugar, rate_hz=150.0, target="g"))
ra = np.asarray(spike_rates_hz(ref.counts, T, 0.1))
rb = np.asarray(spike_rates_hz(hw.counts, T, 0.1))
print("reference active neurons:", int((ra > 0.5).sum()))
print("parity(ref, hw):", parity(ra, rb).summary())

# 3. tile-gated Pallas delivery (the TPU-native event path) — bit-identical
# spike counts to csr by construction.  On CPU the kernel runs in Pallas
# interpret mode, which unrolls every stored tile at trace time, so the
# demo uses a reduced network; the compiled TPU path handles full scale.
c_small = synthetic_flywire(n=1500, target_synapses=45_000, seed=0)
stim = PoissonDrive(idx=sugar, rate_hz=150.0)
s_ref = simulate(c_small, SimConfig(engine="csr"), 200, seed=1,
                 stimulus=stim)
s_blk = simulate(c_small, SimConfig(engine="blocked"), 200, seed=1,
                 stimulus=stim)
print("blocked == csr spike counts:",
      bool(np.array_equal(np.asarray(s_ref.counts),
                          np.asarray(s_blk.counts))))
