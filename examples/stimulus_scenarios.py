"""Tour of the experiment subsystem (repro.exp): composable stimuli,
in-scan probes, vmapped trial batches, and the scenario registry.

    PYTHONPATH=src python examples/stimulus_scenarios.py
"""

import numpy as np

from repro.core import SimConfig, parity, synthetic_flywire_cached
from repro.exp import (Background, Compose, PoissonDrive, ProbeSpec,
                       available_scenarios, build_scenario, get_scenario,
                       run_trials)

c = synthetic_flywire_cached(n=5_000, seed=0, target_synapses=150_000)
cfg = SimConfig(engine="csr")
T = 1000   # 100 ms at dt=0.1

# --- the scenario catalog -------------------------------------------------
print("scenarios:")
for name in available_scenarios():
    print(f"  {name:18s} {get_scenario(name).description}")

# --- one scenario, fully probed ------------------------------------------
stim = build_scenario("sugar_feeding", c, cfg)
sugar_ids = tuple(int(i) for i in np.asarray(stim.parts[0].idx)[:4])
res = run_trials(c, cfg, T, stimulus=stim, seeds=1,
                 probes=ProbeSpec(raster=True, voltage=sugar_ids,
                                  pop_rate=True, drops=True))
print(f"\nsugar_feeding: {int(np.asarray(res.counts).sum())} spikes; "
      f"records: " + ", ".join(f"{k}{tuple(v.shape)}"
                               for k, v in sorted(res.records.items())))

# --- trial-averaged parity between engines (one compiled call each) ------
a = run_trials(c, cfg, T, stimulus=stim, seeds=5)
b = run_trials(c, SimConfig(engine="event"), T, stimulus=stim, seeds=5)
print("csr vs event (5-trial mean rates):",
      parity(a.mean_rates_hz(T, 0.1), b.mean_rates_hz(T, 0.1)).summary())

# --- composing a custom scenario inline ----------------------------------
custom = Compose((
    PoissonDrive(idx=stim.parts[0].idx, rate_hz=300.0),
    Background(rate_hz=2.0),
))
r = run_trials(c, cfg, T, stimulus=custom, seeds=3)
print(f"custom 300Hz sugar + 2Hz background: "
      f"{np.asarray(r.counts).sum(axis=1)} spikes per trial")
