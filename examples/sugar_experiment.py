"""The paper's full pipeline: connectome -> greedy capacity partitioning
-> SNN-dCSR -> distributed event-driven simulation -> parity validation
(paper §3: Brian2 -> STACS -> Loihi 2, here: csr -> partitioned shard_map).

    PYTHONPATH=src python examples/sugar_experiment.py [--cores 4] [--full]
"""

import argparse

import numpy as np

from repro.core import (CoreBudget, SimConfig, caps_from_budget,
                        compression_report, greedy_partition, parity,
                        synthetic_flywire_cached)
from repro.core.dcsr import build_dcsr, edge_cut
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.partition import pad_to_uniform, partition_report
from repro.exp import PoissonDrive, run_trials

ap = argparse.ArgumentParser()
ap.add_argument("--cores", type=int, default=4)
ap.add_argument("--full", action="store_true")
args = ap.parse_args()

n, syn = (139_255, 15_000_000) if args.full else (10_000, 300_000)
c = synthetic_flywire_cached(n=n, seed=0, target_synapses=syn)
sugar = np.arange(20, dtype=np.int32)
print("connectome:", c.stats())

# --- compression statistics (paper Fig 7) ---
budget = CoreBudget.loihi2()
p = greedy_partition(c, caps_from_budget(budget, "sar"), scheme="sar")
print("compression:", compression_report(c, p.part_of_neuron))
rep = partition_report(c, p, budget)
print(f"loihi partitioning: {p.n_parts} cores "
      f"(~{int(np.ceil(p.n_parts/120))} chips), "
      f"mem util mean {rep['mem_util'].mean():.1%}")

# --- distributed simulation over host partitions ---
p_tpu = pad_to_uniform(p, args.cores, c.n)
d = build_dcsr(c, p_tpu, quantize_bits=9)
print("dcsr:", edge_cut(d))
sim = SimConfig(engine="csr", quantize_bits=9, fixed_point=True,
                poisson_to_v=False)
T = 1000
# Poisson as synaptic drive (Loihi approximation) — addressed in original
# neuron ids; simulate_distributed shards it onto the partitioning
stim = PoissonDrive(idx=sugar, rate_hz=150.0, target="g")
res = simulate_distributed(d, DistConfig(sim=sim, scheme="event"), T,
                           seed=0, emulate=True, stimulus=stim)
print(f"distributed sim: {int(res.counts.sum())} spikes, "
      f"dropped {res.dropped}")

# --- parity vs the monolithic float reference (paper Figs 6/12):
# a vmapped 3-trial batch, one compiled call (repro.exp.run_trials) ---
ref = run_trials(c, SimConfig(engine="csr"), T, seeds=[5, 6, 7],
                 stimulus=PoissonDrive(idx=sugar, rate_hz=150.0))
ra = ref.mean_rates_hz(T, 0.1)
rb = res.counts / (T * 0.1e-3)
print("parity:", parity(ra, rb).summary())
