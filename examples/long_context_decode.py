"""Long-context decode example: rwkv6 (O(1) state) decoding against a
large position index — the mechanism behind the long_500k cell.

    PYTHONPATH=src python examples/long_context_decode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params, prefill

cfg = get_config("rwkv6-7b", smoke=True)
params = init_params(jax.random.PRNGKey(0), cfg)

# prefill a prompt, then decode many tokens: state stays O(1)
prompt = jnp.asarray(np.arange(64) % cfg.vocab)[None]
logits, cache = prefill(params, {"tokens": prompt}, cfg, max_len=0)
tok = jnp.argmax(logits, -1)
jit_decode = jax.jit(lambda c, t, p: decode_step(params, c, t, p, cfg))
for pos in range(64, 96):
    logits, cache = jit_decode(cache, tok, jnp.int32(pos))
    tok = jnp.argmax(logits, -1)
state_bytes = sum(x.nbytes for x in jax.tree.leaves(cache))
print(f"decoded 32 tokens; recurrent state is {state_bytes/1024:.1f} KiB "
      f"regardless of context length (vs a KV cache growing linearly)")
