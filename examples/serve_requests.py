"""Batched serving example: continuous batching over decode slots.

    PYTHONPATH=src python examples/serve_requests.py
"""

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serving import Request, ServeConfig, ServingEngine

cfg = get_config("qwen2.5-14b", smoke=True)
params = init_params(jax.random.PRNGKey(0), cfg)
engine = ServingEngine(params, cfg, ServeConfig(batch_slots=4, max_len=96))

rng = np.random.default_rng(0)
requests = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, 4 + i % 9),
                    max_new=16) for i in range(12)]
t0 = time.time()
done = engine.run(list(requests))
dt = time.time() - t0
tokens = sum(len(r.out) for r in done)
print(f"served {len(done)} requests / {tokens} tokens "
      f"in {dt:.2f}s ({tokens/dt:.1f} tok/s on CPU smoke model)")
for r in done[:3]:
    print(f"  rid={r.rid} prompt_len={len(r.prompt)} out={r.out}")
