"""End-to-end driver: train a ~100M-parameter qwen-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + straggler
detection (the task's end-to-end training example).

    PYTHONPATH=src python examples/train_lm.py --steps 300
"""

import argparse
import time

import jax

from repro.models import ModelConfig, init_params, count_params
from repro.data import SyntheticLM
from repro.optim import AdamW, cosine_schedule
from repro.train import StragglerDetector, make_train_step, save_checkpoint
from repro.train.train_step import init_train_state

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

# ~100M params: 10 layers x d_model 640, GQA 10/2, vocab 16384
cfg = ModelConfig(name="demo-100m", family="dense", n_layers=10,
                  d_model=640, n_heads=10, n_kv_heads=2, d_ff=2560,
                  vocab=16384, act="silu", norm="rms")
print(f"model: {count_params(cfg)/1e6:.1f}M params")

params = init_params(jax.random.PRNGKey(0), cfg)
opt = AdamW(lr=cosine_schedule(3e-4, 20, args.steps))
state = init_train_state(params, opt)
step = jax.jit(make_train_step(cfg, opt, microbatches=2), donate_argnums=0)
ds = SyntheticLM(vocab=cfg.vocab, seq=args.seq, global_batch=args.batch)
det = StragglerDetector()

for i in range(args.steps):
    t0 = time.time()
    state, m = step(state, ds.batch_at(i))
    if det.observe(i, time.time() - t0):
        print(f"straggler at step {i}")
    if i % 20 == 0:
        print(f"step {i:4d} loss {float(m['loss']):.4f} "
              f"lr {float(m['lr']):.2e} gnorm {float(m['grad_norm']):.2f}")
    if (i + 1) % 100 == 0:
        save_checkpoint(args.ckpt_dir, i + 1, state, async_save=True)

print(f"final loss {float(m['loss']):.4f}")
