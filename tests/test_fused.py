"""Fused delivery->LIF kernel: bit-identity to the unfused composition and
the integrates-once capability contract.

The ``blocked_fused`` engine (and the fused path of the sharded ``blocked``
exchange scheme) runs spike delivery and the LIF update in one Pallas
kernel, with the delivered current living only in a VMEM accumulator.  That
is a *scheduling* change, not an arithmetic one: every test here pins
bit-identity against the unfused blocked + ``lif_step``/``lif_step_fx``
composition, in float32 and the Loihi-faithful int32 Q19.12 path
(interpret mode on CPU; the same kernels compile on TPU).

The capability flag (``integrates_lif`` / ``fuses_lif``) is what keeps the
shared step body from integrating twice — its contract gets its own tests.
"""

import numpy as np
import pytest
from conftest import HAVE_HYPOTHESIS, given, requires_hypothesis, settings, st

from repro.core import (SimConfig, available_engines, get_engine, simulate,
                        synthetic_flywire)
from repro.core.engines import engine_integrates_lif
from repro.core.exchange import available_schemes, get_scheme

T_STEPS = 200


@pytest.fixture(scope="module")
def net():
    c = synthetic_flywire(n=1000, target_synapses=25_000, seed=5)
    return c, np.arange(20)


def _cfg(engine, fx, **kw):
    # poisson_to_v=False on the fixed-point path mirrors the Loihi ablation
    # and keeps both drive channels (g_units + force) exercised
    kw.setdefault("background_rate_hz", 2.0)
    return SimConfig(engine=engine, quantize_bits=9, fixed_point=fx,
                     poisson_to_v=not fx, **kw)


def _assert_bit_identical(a, b):
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))
    for la, lb, name in zip(a.state, b.state, ("v", "g", "refrac")):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=name)
    assert int(a.dropped) == int(b.dropped)


# ------------------------------------------------------------------------
# Monolithic engine: blocked_fused vs blocked + the step body's LIF update
# ------------------------------------------------------------------------

@pytest.mark.parametrize("fx", [False, True], ids=["f32", "q19.12"])
def test_fused_engine_bit_identical_to_unfused(net, fx):
    c, sugar = net
    ref = simulate(c, _cfg("blocked", fx), T_STEPS, sugar, seed=7)
    out = simulate(c, _cfg("blocked_fused", fx), T_STEPS, sugar, seed=7)
    assert int(out.counts.sum()) > 0
    _assert_bit_identical(ref, out)


def test_fused_engine_matches_csr_reference(net):
    """Transitivity anchor: fused == blocked == csr on the same stream."""
    c, sugar = net
    ref = simulate(c, _cfg("csr", False), T_STEPS, sugar, seed=3)
    out = simulate(c, _cfg("blocked_fused", False), T_STEPS, sugar, seed=3)
    np.testing.assert_array_equal(np.asarray(ref.counts),
                                  np.asarray(out.counts))


# ------------------------------------------------------------------------
# Distributed: fused path under the sharded blocked exchange scheme (P=4)
# ------------------------------------------------------------------------

def _dist(c, engine, fx, t_steps, caps=None, seed=11, background_hz=2.0):
    from repro.core.dcsr import build_dcsr
    from repro.core.distributed import DistConfig, simulate_distributed
    from repro.core.partition import even_partition
    d = build_dcsr(c, even_partition(c, 4), quantize_bits=9)
    sim = _cfg(engine, fx, background_rate_hz=background_hz)
    dcfg = DistConfig(sim=sim, scheme="blocked",
                      **(caps or {}))
    return simulate_distributed(d, dcfg, t_steps, np.arange(20), seed=seed,
                                emulate=True)


@pytest.mark.parametrize("fx", [False, True], ids=["f32", "q19.12"])
def test_fused_blocked_scheme_bit_identical_P4(net, fx):
    """sim.engine='blocked_fused' flips the blocked scheme onto its fused
    kernel; exchange, RNG stream, drop accounting and tile counters must
    be unchanged — and the result bit-identical to the unfused scheme."""
    c, _ = net
    ref = _dist(c, "csr", fx, 120)           # scheme-local delivery unfused
    out = _dist(c, "blocked_fused", fx, 120)
    assert int(out.counts.sum()) > 0
    np.testing.assert_array_equal(ref.counts, out.counts)
    for la, lb, name in zip(ref.state, out.state, ("v", "g", "refrac")):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=name)
    assert ref.dropped == out.dropped
    for k in ("tiles_live", "tiles_skipped"):
        assert int(ref.stats[k]) == int(out.stats[k])


def test_fused_blocked_scheme_overflow_drops_match(net):
    """Under a starved event capacity the fused path must count exactly the
    same capacity-overflow drops (synapse units) as the unfused scheme —
    fusion changes where integration happens, never what is lost."""
    c, _ = net
    caps = dict(spike_capacity=2, block_capacity=1)
    ref = _dist(c, "csr", False, 120, caps=caps, background_hz=200.0)
    out = _dist(c, "blocked_fused", False, 120, caps=caps,
                background_hz=200.0)
    assert out.dropped > 0                    # deliberately starved
    assert ref.dropped == out.dropped
    np.testing.assert_array_equal(ref.counts, out.counts)


# ------------------------------------------------------------------------
# Capability flag: integration happens exactly once
# ------------------------------------------------------------------------

def test_capability_flag_consistency():
    """Registry invariant: an engine/scheme advertises ``integrates_lif`` /
    ``fuses_lif`` iff it actually provides the fused entry point — a flag
    without an implementation (or vice versa) could silently double- or
    zero-integrate."""
    for name in available_engines():
        eng = get_engine(name)
        assert engine_integrates_lif(name) == hasattr(eng, "deliver_fused"), \
            name
    assert engine_integrates_lif("blocked_fused")
    assert not engine_integrates_lif("blocked")
    for name in available_schemes():
        scheme = get_scheme(name)
        assert hasattr(scheme, "fuses_lif") == \
            hasattr(scheme, "deliver_fused"), name


def test_fused_step_skips_apply_drive(net, monkeypatch):
    """The step body must not run its own LIF update when the engine
    already integrated (double integration), and must run it exactly once
    per traced step otherwise."""
    import repro.exp.stimulus as stim_mod
    c, sugar = net
    calls = {"n": 0}
    real = stim_mod.apply_drive

    def counting(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(stim_mod, "apply_drive", counting)
    # unique t_steps so each run traces freshly under the patched function
    simulate(c, _cfg("blocked_fused", False), 7, sugar, seed=0)
    assert calls["n"] == 0, "fused engine must bypass the step-body LIF"
    simulate(c, _cfg("blocked", False), 9, sugar, seed=0)
    assert calls["n"] == 1, "unfused engine must integrate exactly once"


@requires_hypothesis
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), fx=st.booleans(),
       rate=st.floats(0.0, 0.3))
def test_deliver_fused_equals_deliver_then_integrate(seed, fx, rate):
    """Property: for ANY spike pattern, LIF state and drive, the fused
    kernel's one-call result equals the unfused deliver + apply_drive
    composition bit-for-bit (both jitted — the contract is between the two
    compiled programs the step body can choose between)."""
    import jax
    import jax.numpy as jnp

    from repro.core.neuron import LIFState
    from repro.exp.stimulus import StimDrive, apply_drive

    c = _PROP_NET
    cfg = SimConfig(engine="blocked_fused", quantize_bits=9, fixed_point=fx)
    syn = _prop_syn(cfg)
    eng = get_engine("blocked_fused")
    rng = np.random.default_rng(seed)
    n = c.n
    spikes = jnp.asarray(rng.random(n) < rate)
    if fx:
        lif = LIFState(v=jnp.asarray(rng.integers(-30000, 40000, n), jnp.int32),
                       g=jnp.asarray(rng.integers(0, 9000, n), jnp.int32),
                       refrac=jnp.asarray(rng.integers(0, 3, n), jnp.int32))
    else:
        lif = LIFState(v=jnp.asarray(rng.normal(0, 3, n), jnp.float32),
                       g=jnp.asarray(abs(rng.normal(0, 1, n)), jnp.float32),
                       refrac=jnp.asarray(rng.integers(0, 3, n), jnp.int32))
    drive = StimDrive(v_mv=jnp.asarray(rng.normal(0, 2, n), jnp.float32),
                      g_units=jnp.asarray(rng.normal(0, 5, n), jnp.float32),
                      force=jnp.asarray(rng.random(n) < 0.02))

    @jax.jit
    def composed(lif, drive, spikes):
        g_units, _ = eng.deliver(syn, spikes, cfg)
        return apply_drive(lif, g_units, drive, cfg.params, fx)

    @jax.jit
    def fused(lif, drive, spikes):
        new_lif, spk, _ = eng.deliver_fused(syn, spikes, lif, drive, cfg)
        return new_lif, spk

    rl, rs = composed(lif, drive, spikes)
    fl, fs = fused(lif, drive, spikes)
    for a, b, name in zip(fl, rl, ("v", "g", "refrac")):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)
    np.testing.assert_array_equal(np.asarray(fs), np.asarray(rs))


if HAVE_HYPOTHESIS:
    # module-scope net/state for the property test (hypothesis forbids
    # function-scoped fixtures; the build is amortized across examples)
    _PROP_NET = synthetic_flywire(n=600, target_synapses=15_000, seed=8)
    _PROP_SYN = {}

    def _prop_syn(cfg):
        key = cfg.fixed_point
        if key not in _PROP_SYN:
            _PROP_SYN[key] = get_engine("blocked_fused").build(_PROP_NET, cfg)
        return _PROP_SYN[key]
