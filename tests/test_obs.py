"""Telemetry layer (PR 7 acceptance): tracing spans, compile-cache
metrics, streamed JSONL events, and the run-report CLI.

Pins: (a) telemetry is bit-neutral — raster/records/state digests are
identical with a session active or not, on float32 AND Q19.12,
monolithic and distributed (P=4 emulate); (b) every emitted record
validates against the committed ``schema.json`` (enforced live via
``validate=True`` and again offline via ``validate_stream``); (c) the
chunk event stream is exactly ceil(T/K) records whose steps sum to T;
(d) spans nest, time, and no-op without a session; (e) the
compile-cache wrapper counts hits/misses per signature, dispatches
bit-identically, and falls back (permanently, flagged) when AOT
compilation is impossible; (f) checkpoint / health / restart /
escalation events fire at the supervision points that produced them;
(g) the report CLI renders a non-empty summary from any valid stream.
"""

import json
import math
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (CapacityConfig, HealthConfig, SimConfig,
                        run_resilient, simulate, synthetic_flywire)
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.partition import even_partition
from repro.exp import ProbeSpec
from repro.obs.report import summarize
from repro.obs.schema import validate_record, validate_stream


@pytest.fixture(scope="module")
def setup():
    c = synthetic_flywire(n=400, target_synapses=8_000, seed=0)
    sugar = np.arange(80)
    d = build_dcsr(c, even_partition(c, 4))
    return c, sugar, d


PROBES = ProbeSpec(raster=True, pop_rate=True)


def _run(c, cfg, t, sugar, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return simulate(c, cfg, t, sugar_neurons=sugar, seed=3,
                        probes=PROBES, **kw)


def _run_dist(d, dcfg, t, sugar, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return simulate_distributed(d, dcfg, t, sugar_neurons=sugar, seed=3,
                                    emulate=True, probes=PROBES, **kw)


def _assert_bitwise(a, b):
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert np.array_equal(np.asarray(a.raster), np.asarray(b.raster))
    for k in a.records:
        assert np.array_equal(np.asarray(a.records[k]),
                              np.asarray(b.records[k])), k
    assert np.array_equal(np.asarray(a.state.v), np.asarray(b.state.v))
    assert int(np.asarray(a.dropped).sum()) == int(np.asarray(b.dropped).sum())


# --------------------------------------------------------------------------
# (a) telemetry is bit-neutral
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine,fx", [("csr", False), ("event", False),
                                       ("event", True)])
def test_telemetry_bit_identity_monolithic(setup, engine, fx):
    """With a session active, simulate() routes through the chunk driver;
    the results must stay bitwise what the bare monolithic scan makes."""
    c, sugar, _ = setup
    cfg = SimConfig(engine=engine, fixed_point=fx)
    ref = _run(c, cfg, 50, sugar)
    with obs.telemetry(validate=True):
        tele = _run(c, cfg, 50, sugar)
        tele_chunked = _run(c, cfg, 50, sugar, chunk_steps=16)
    _assert_bitwise(ref, tele)
    _assert_bitwise(ref, tele_chunked)


def test_telemetry_bit_identity_distributed(setup):
    c, sugar, d = setup
    dcfg = DistConfig(sim=SimConfig(engine="event"), scheme="event")
    ref = _run_dist(d, dcfg, 50, sugar)
    with obs.telemetry(validate=True):
        tele = _run_dist(d, dcfg, 50, sugar)
    _assert_bitwise(ref, tele)


def test_compile_cache_in_stats_only_with_session(setup):
    c, sugar, _ = setup
    cfg = SimConfig(engine="csr")
    assert "compile_cache" not in _run(c, cfg, 10, sugar).stats
    with obs.telemetry():
        cc = _run(c, cfg, 10, sugar).stats["compile_cache"]
    assert set(cc) == {"hits", "misses", "signatures"}
    assert cc["misses"] >= 1


# --------------------------------------------------------------------------
# (b)+(c) event stream: schema-valid, chunk arithmetic exact
# --------------------------------------------------------------------------

def test_event_stream_schema_and_chunks(setup, tmp_path):
    c, sugar, _ = setup
    path = tmp_path / "run.jsonl"
    # K=13 -> signatures fresh in this process, so compile events appear
    t_steps, K = 50, 13
    with obs.telemetry(str(path), validate=True):
        _run(c, SimConfig(engine="event"), t_steps, sugar, chunk_steps=K)
    assert validate_stream(str(path)) == []
    events = [json.loads(l) for l in path.read_text().splitlines()]
    kinds = {e["type"] for e in events}
    assert {"run_start", "chunk", "span", "compile", "run_end"} <= kinds
    chunks = [e for e in events if e["type"] == "chunk"]
    assert len(chunks) == math.ceil(t_steps / K)
    assert sum(e["steps"] for e in chunks) == t_steps
    assert [e["step"] for e in chunks] == [13, 26, 39, 50]
    # cumulative counters are monotone; deltas reconcile exactly
    prev = 0
    for e in chunks:
        assert e["counters"]["spikes"] - prev == e["delta"]["spikes"]
        prev = e["counters"]["spikes"]
    # the t clock is monotone across the stream
    ts = [e["t"] for e in events]
    assert ts == sorted(ts)
    start = next(e for e in events if e["type"] == "run_start")
    assert start["kind"] == "simulate" and start["n"] == c.n
    end = next(e for e in events if e["type"] == "run_end")
    assert end["steps"] == t_steps
    assert end["counters"]["spikes"] == chunks[-1]["counters"]["spikes"]


def test_distributed_event_stream(setup, tmp_path):
    c, sugar, d = setup
    path = tmp_path / "dist.jsonl"
    with obs.telemetry(str(path), validate=True):
        _run_dist(d, DistConfig(sim=SimConfig(engine="event"),
                                scheme="event"), 30, sugar, chunk_steps=10)
    assert validate_stream(str(path)) == []
    events = [json.loads(l) for l in path.read_text().splitlines()]
    start = next(e for e in events if e["type"] == "run_start")
    assert start["kind"] == "simulate_distributed"
    assert start["scheme"] == "event"
    assert len([e for e in events if e["type"] == "chunk"]) == 3


def test_checkpoint_events(setup, tmp_path):
    c, sugar, _ = setup
    path = tmp_path / "run.jsonl"
    with obs.telemetry(str(path), validate=True):
        _run(c, SimConfig(engine="csr"), 40, sugar, chunk_steps=10,
             checkpoint_dir=str(tmp_path / "ckpt"))
    events = [json.loads(l) for l in path.read_text().splitlines()]
    ckpts = [e for e in events if e["type"] == "checkpoint"]
    assert [e["step"] for e in ckpts] == [10, 20, 30, 40]
    assert all(e["async_save"] is False for e in ckpts)


def test_health_breach_event(setup, tmp_path):
    c, sugar, _ = setup
    path = tmp_path / "run.jsonl"
    cfg = SimConfig(engine="csr",
                    health=HealthConfig(rate_lo_hz=1e9))   # trips chunk 1
    with obs.telemetry(str(path), validate=True):
        with pytest.raises(Exception, match="rate_envelope"):
            _run(c, cfg, 40, sugar, chunk_steps=10)
    assert validate_stream(str(path)) == []
    events = [json.loads(l) for l in path.read_text().splitlines()]
    [breach] = [e for e in events if e["type"] == "health"]
    assert breach["kind"] == "rate_envelope" and breach["step"] == 10


def test_restart_event_from_run_resilient(tmp_path):
    path = tmp_path / "run.jsonl"
    calls = []

    def run_fn(resume, capacity):
        calls.append(resume)
        if len(calls) == 1:
            raise RuntimeError("injected crash")
        return "done"

    with obs.telemetry(str(path), validate=True):
        assert run_resilient(run_fn) == "done"
    events = [json.loads(l) for l in path.read_text().splitlines()]
    [restart] = [e for e in events if e["type"] == "restart"]
    assert restart["attempt"] == 1 and restart["error"] == "RuntimeError"
    assert restart["resume_step"] is None          # no checkpoint_dir
    assert any(e["type"] == "span" and e["name"] == "run_resilient"
               for e in events)


def test_escalation_event_from_run_resilient(tmp_path):
    from repro.core.health import SimulationHealthError
    path = tmp_path / "run.jsonl"
    calls = []

    def run_fn(resume, capacity):
        calls.append(capacity)
        if len(calls) == 1:
            raise SimulationHealthError("drop_rate", 10, 3.5, 1.0)
        return capacity

    with obs.telemetry(str(path), validate=True):
        cap = run_resilient(run_fn, capacity=CapacityConfig(
            spike_capacity=8, syn_budget=64, block_capacity=8))
    assert cap.spike_capacity > 8                  # escalated
    events = [json.loads(l) for l in path.read_text().splitlines()]
    [esc] = [e for e in events if e["type"] == "escalation"]
    assert esc["attempt"] == 1 and esc["kind"] == "drop_rate"


# --------------------------------------------------------------------------
# (d) spans
# --------------------------------------------------------------------------

def test_span_noop_without_session():
    with obs.span("anything", extra=1) as sp:
        pass
    assert sp.wall_s is None
    assert obs.active() is None


def test_span_nesting_depth_and_metrics():
    got = []
    with obs.telemetry(got.append) as tele:
        with obs.span("outer"):
            with obs.span("inner", tag="x") as sp:
                pass
        assert sp.wall_s is not None and sp.wall_s >= 0
        o = tele.metrics.observations()
        assert o["phase.outer"]["count"] == 1
        assert o["phase.inner"]["count"] == 1
    by_name = {e["name"]: e for e in got if e["type"] == "span"}
    assert by_name["inner"]["depth"] == 1
    assert by_name["outer"]["depth"] == 0
    assert by_name["inner"]["attrs"] == {"tag": "x"}
    # inner closes before outer -> emitted first
    names = [e["name"] for e in got if e["type"] == "span"]
    assert names.index("inner") < names.index("outer")


def test_telemetry_session_scoping():
    with obs.telemetry() as tele:
        assert obs.active() is tele
        with obs.telemetry() as inner:
            assert obs.active() is inner
        assert obs.active() is tele
    assert obs.active() is None


# --------------------------------------------------------------------------
# (e) compile-cache wrapper
# --------------------------------------------------------------------------

def test_instrumented_jit_hit_miss_and_identity():
    base = jax.jit(lambda x, k: x * k, static_argnums=(1,))
    wrapped = obs.InstrumentedJit(base, "test.mul", static_argnums=(1,))
    x = jnp.arange(8.0)
    plain = wrapped(x, 3)                      # no session: passthrough
    with obs.telemetry(validate=True) as tele:
        a = wrapped(x, 3)                      # miss -> AOT compile
        b = wrapped(x + 1, 3)                  # same signature -> hit
        wrapped(x, 4)                          # new static -> miss
        wrapped(jnp.arange(4.0), 3)            # new shape -> miss
        cc = tele.metrics.compile_snapshot()
    assert np.array_equal(np.asarray(a), np.asarray(plain))
    assert np.array_equal(np.asarray(b), np.asarray(x * 3 + 3))
    assert cc["misses"] == 3 and cc["hits"] == 1
    assert len(cc["signatures"]) == 3
    sigs = {r["signature"] for r in cc["signatures"]}
    assert len(sigs) == 3
    assert all(not r["fallback"] for r in cc["signatures"])


def test_instrumented_jit_fallback_never_breaks_the_call():
    calls = []

    class NotLowerable:
        def __call__(self, x):
            calls.append("plain")
            return x + 1
        # .lower is missing -> AttributeError -> permanent fallback

    wrapped = obs.InstrumentedJit(NotLowerable(), "test.fallback")
    with obs.telemetry(validate=True) as tele:
        out = wrapped(jnp.float32(1.0))
        wrapped(jnp.float32(2.0))
        cc = tele.metrics.compile_snapshot()
    assert float(out) == 2.0
    assert calls == ["plain", "plain"]
    assert cc["misses"] == 1 and cc["hits"] == 1
    [rec] = cc["signatures"]
    assert rec["fallback"] is True


# --------------------------------------------------------------------------
# sinks
# --------------------------------------------------------------------------

def test_jsonl_sink_async_close_flushes(tmp_path):
    path = tmp_path / "s.jsonl"
    sink = obs.JsonlSink(str(path), async_flush=True)
    for i in range(100):
        sink.emit({"t": float(i), "type": "span", "name": "x",
                   "wall_s": 0.0, "depth": 0})
    sink.close()
    lines = path.read_text().splitlines()
    assert len(lines) == 100
    assert json.loads(lines[99])["t"] == 99.0
    sink.close()                                   # idempotent


def test_jsonl_sink_write_error_surfaces_at_close(tmp_path):
    path = tmp_path / "s.jsonl"
    sink = obs.JsonlSink(str(path), async_flush=True)
    sink._file.close()                             # force the writer to fail
    sink.emit({"t": 0.0, "type": "span"})
    with pytest.raises(ValueError):
        sink.close()


def test_jsonable_coercion(tmp_path):
    path = tmp_path / "s.jsonl"
    with obs.telemetry(str(path)):
        obs.active().emit("checkpoint", step=np.int64(7), async_save=False)
    rec = json.loads(path.read_text())
    assert rec["step"] == 7 and isinstance(rec["step"], int)


# --------------------------------------------------------------------------
# schema validator
# --------------------------------------------------------------------------

def test_validate_record_rejects_bad_records():
    assert validate_record({"type": "chunk"})              # missing t
    assert validate_record({"t": 0.0, "type": "nope"})     # unknown type
    assert validate_record({"t": 0.0, "type": "chunk", "step": 1})
    # bool must not satisfy integer/number
    bad = validate_record({"t": 0.0, "type": "checkpoint", "step": True})
    assert any("expected integer" in e for e in bad)
    ok = {"t": 0.0, "type": "chunk", "step": 16, "steps": 16,
          "wall_s": 0.1, "steps_per_s": 160.0,
          "counters": {"spikes": 3}, "delta": {"spikes": 3}}
    assert validate_record(ok) == []
    bad = dict(ok, counters={"spikes": "three"})
    assert any("counters" in e for e in validate_record(bad))


def test_validate_stream_empty_is_error(tmp_path):
    p = tmp_path / "e.jsonl"
    p.write_text("")
    assert validate_stream(str(p))


# --------------------------------------------------------------------------
# (g) report CLI
# --------------------------------------------------------------------------

def test_report_renders_real_stream(setup, tmp_path, capsys):
    c, sugar, _ = setup
    path = tmp_path / "run.jsonl"
    with obs.telemetry(str(path), validate=True):
        _run(c, SimConfig(engine="event"), 50, sugar, chunk_steps=16)
    events = [json.loads(l) for l in path.read_text().splitlines()]
    text = summarize(events)
    assert "run: simulate (event)" in text
    assert "throughput: 50 steps" in text
    assert "phases (spans):" in text
    assert "compile cache:" in text
    from repro.obs.report import main
    assert main([str(path)]) == 0
    assert capsys.readouterr().out.strip()


def test_report_exit_codes(tmp_path, capsys):
    from repro.obs.report import main
    p = tmp_path / "empty.jsonl"
    p.write_text("")
    assert main([str(p)]) == 1
    from repro.obs.check import main as check_main
    good = tmp_path / "ok.jsonl"
    good.write_text(json.dumps({"t": 0.0, "type": "span", "name": "x",
                                "wall_s": 0.0, "depth": 0}) + "\n")
    assert check_main([str(good)]) == 0
    assert check_main([str(p), str(good)]) == 1
