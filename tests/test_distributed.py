"""Distributed multi-core simulator (paper §3.2.2-3.2.3): emulated vmap
semantics in-process + real shard_map in a multi-device subprocess."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import SimConfig, parity, simulate, synthetic_flywire
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.partition import even_partition


@pytest.fixture(scope="module")
def setup():
    c = synthetic_flywire(n=1600, target_synapses=48_000, seed=8)
    sugar = np.arange(20)
    p = even_partition(c, 4)
    d = build_dcsr(c, p)
    return c, sugar, d


def test_bitmap_equals_event_scheme(setup):
    """The two comm schemes deliver identical spikes given the same RNG —
    they differ only in message format (paper's SSD vs SAR framing)."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr")
    rb = simulate_distributed(d, DistConfig(sim=sim, scheme="bitmap"), 300,
                              sugar, seed=3, emulate=True)
    re_ = simulate_distributed(d, DistConfig(sim=sim, scheme="event"), 300,
                               sugar, seed=3, emulate=True)
    np.testing.assert_array_equal(rb.counts, re_.counts)
    assert re_.dropped == 0


def test_distributed_parity_with_single_device(setup):
    """Spike-rate parity across implementations — the paper's validation
    statistic (Fig 6/12), applied distributed-vs-monolithic."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr")
    T, trials = 400, 3
    rs = [np.asarray(simulate(c, sim, T, sugar, seed=s).counts)
          for s in range(trials)]
    rd = [simulate_distributed(d, DistConfig(sim=sim, scheme="event"), T,
                               sugar, seed=50 + s, emulate=True).counts
          for s in range(trials)]
    ra = np.stack(rs).mean(0) / (T * 0.1e-3)
    rb = np.stack(rd).mean(0) / (T * 0.1e-3)
    st = parity(ra, rb, active_thresh_hz=1.0)
    assert st.pearson_r > 0.8, st.summary()


def test_event_capacity_drop_accounting(setup):
    c, sugar, d = setup
    sim = SimConfig(engine="csr", background_rate_hz=300.0)
    r = simulate_distributed(
        d, DistConfig(sim=sim, scheme="event", spike_capacity=4,
                      syn_budget=256), 50, sugar, seed=0, emulate=True)
    assert r.dropped > 0


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.core import SimConfig, synthetic_flywire
    from repro.core.dcsr import build_dcsr
    from repro.core.distributed import DistConfig, simulate_distributed
    from repro.core.partition import even_partition

    c = synthetic_flywire(n=1600, target_synapses=48_000, seed=8)
    sugar = np.arange(20)
    d = build_dcsr(c, even_partition(c, 4))
    sim = SimConfig(engine="csr")
    for scheme in ("bitmap", "event"):
        cfg = DistConfig(sim=sim, scheme=scheme)
        emu = simulate_distributed(d, cfg, 200, sugar, seed=3, emulate=True)
        real = simulate_distributed(d, cfg, 200, sugar, seed=3, emulate=False)
        assert (emu.counts == real.counts).all(), scheme
        print(scheme, "ok", int(real.counts.sum()))
""")


def test_shard_map_matches_emulation(tmp_path):
    """The real shard_map execution on 4 host devices is bit-identical to
    the vmap emulation."""
    script = tmp_path / "run_shard_map.py"
    script.write_text(SHARD_MAP_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "bitmap ok" in out.stdout and "event ok" in out.stdout
