"""Distributed multi-core simulator (paper §3.2.2-3.2.3): emulated vmap
semantics in-process + real shard_map in a multi-device subprocess."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import SimConfig, parity, simulate, synthetic_flywire
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.partition import even_partition


@pytest.fixture(scope="module")
def setup():
    c = synthetic_flywire(n=1600, target_synapses=48_000, seed=8)
    sugar = np.arange(20)
    p = even_partition(c, 4)
    d = build_dcsr(c, p)
    return c, sugar, d


def test_bitmap_equals_event_scheme(setup):
    """The two comm schemes deliver identical spikes given the same RNG —
    they differ only in message format (paper's SSD vs SAR framing)."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr")
    rb = simulate_distributed(d, DistConfig(sim=sim, scheme="bitmap"), 300,
                              sugar, seed=3, emulate=True)
    re_ = simulate_distributed(d, DistConfig(sim=sim, scheme="event"), 300,
                               sugar, seed=3, emulate=True)
    np.testing.assert_array_equal(rb.counts, re_.counts)
    assert re_.dropped == 0


def test_distributed_parity_with_single_device(setup):
    """Spike-rate parity across implementations — the paper's validation
    statistic (Fig 6/12), applied distributed-vs-monolithic."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr")
    T, trials = 400, 3
    rs = [np.asarray(simulate(c, sim, T, sugar, seed=s).counts)
          for s in range(trials)]
    rd = [simulate_distributed(d, DistConfig(sim=sim, scheme="event"), T,
                               sugar, seed=50 + s, emulate=True).counts
          for s in range(trials)]
    ra = np.stack(rs).mean(0) / (T * 0.1e-3)
    rb = np.stack(rd).mean(0) / (T * 0.1e-3)
    st = parity(ra, rb, active_thresh_hz=1.0)
    assert st.pearson_r > 0.8, st.summary()


def test_event_capacity_drop_accounting(setup):
    c, sugar, d = setup
    sim = SimConfig(engine="csr", background_rate_hz=300.0)
    r = simulate_distributed(
        d, DistConfig(sim=sim, scheme="event", spike_capacity=4,
                      syn_budget=256), 50, sugar, seed=0, emulate=True)
    assert r.dropped > 0


def test_distributed_event_overflow_exact_vs_numpy(setup):
    """Single-step overflow contract for the sharded event path: per
    partition, the delivered subset must agree with the flat local store on
    every non-dropped synapse, and the summed drop count (budget overruns +
    the global fan-out of spikes beyond the event capacity) must match a
    numpy reference exactly."""
    from repro.core.compaction import derived_block_capacity, two_level_active
    from repro.core.exchange import build_dist_arrays
    from repro.core.exchange.event import deliver_events
    from test_compaction import np_two_level

    c, _, d = setup
    P_, U = d.n_parts, d.part_size
    n_glob = P_ * U
    arrs = build_dist_arrays(d)
    indptr = np.asarray(arrs.out_indptr)
    out_tgt, out_w = np.asarray(arrs.out_tgt), np.asarray(arrs.out_w)
    gfo = np.asarray(arrs.src_gfo)

    rng = np.random.default_rng(5)
    delayed = rng.random((P_, U)) < 0.05
    delayed &= np.asarray(arrs.pad_mask)

    for cap, budget in [(4, 64), (16, 300), (256, 32_768)]:
        bcap = derived_block_capacity(U, cap)
        # per-partition compaction -> the all-gathered global event list
        gids = []
        for p in range(P_):
            idx = np.asarray(two_level_active(delayed[p], cap, bcap))
            np.testing.assert_array_equal(
                idx, np_two_level(delayed[p], cap, bcap))
            gids.append(np.where(idx < U, idx + p * U, n_glob))
        events = np.concatenate(gids).astype(np.int32)

        total_drop = 0
        for p in range(P_):
            g, bdrop = deliver_events(
                events, arrs.out_indptr[p], arrs.out_tgt[p], arrs.out_w[p],
                U, n_glob, budget)
            flat = np.concatenate(
                [np.arange(indptr[p][e], indptr[p][e + 1])
                 for e in events if e < n_glob] or [np.array([], int)])
            g_ref = np.zeros(U + 1, np.float64)
            np.add.at(g_ref, out_tgt[p][flat[:budget]],
                      out_w[p][flat[:budget]])
            np.testing.assert_array_equal(np.asarray(g), g_ref[:U])
            assert int(bdrop) == max(len(flat) - budget, 0)
            kept = np.asarray(gids[p])
            kept = kept[kept < n_glob] - p * U
            over_fo = int(gfo[p][delayed[p]].sum()) - int(gfo[p][kept].sum())
            total_drop += max(len(flat) - budget, 0) + over_fo

        # numpy ground truth: requested global fan-out of every delayed
        # spike minus what the event lists + budgets actually delivered
        requested = int(gfo[delayed].sum())
        delivered = 0
        for p in range(P_):
            tot = sum(int(indptr[p][e + 1] - indptr[p][e])
                      for e in events if e < n_glob)
            delivered += min(tot, budget)
        assert total_drop == requested - delivered
    assert total_drop == 0   # the generous provisioning dropped nothing


SHARD_MAP_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import numpy as np
    from repro.core import SimConfig, synthetic_flywire
    from repro.core.dcsr import build_dcsr
    from repro.core.distributed import DistConfig, simulate_distributed
    from repro.core.partition import even_partition

    c = synthetic_flywire(n=1600, target_synapses=48_000, seed=8)
    sugar = np.arange(20)
    d = build_dcsr(c, even_partition(c, 4))
    sim = SimConfig(engine="csr")
    for scheme in ("bitmap", "event", "blocked"):
        cfg = DistConfig(sim=sim, scheme=scheme)
        emu = simulate_distributed(d, cfg, 200, sugar, seed=3, emulate=True)
        real = simulate_distributed(d, cfg, 200, sugar, seed=3, emulate=False)
        assert (emu.counts == real.counts).all(), scheme
        assert emu.stats.keys() == real.stats.keys()
        print(scheme, "ok", int(real.counts.sum()))

    # trial batching under real shard_map matches sequential runs
    from repro.exp import run_dist_trials
    cfg = DistConfig(sim=sim, scheme="event")
    tr = run_dist_trials(d, cfg, 100, sugar, seeds=[3, 11], emulate=False)
    for i, s in enumerate((3, 11)):
        one = simulate_distributed(d, cfg, 100, sugar, seed=s, emulate=False)
        assert (tr.counts[i] == one.counts).all()
    print("trials ok", int(tr.counts.sum()))
""")


def test_shard_map_matches_emulation(tmp_path):
    """The real shard_map execution on 4 host devices is bit-identical to
    the vmap emulation, for every exchange scheme."""
    script = tmp_path / "run_shard_map.py"
    script.write_text(SHARD_MAP_SCRIPT)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, str(script)], capture_output=True,
                         text=True, timeout=600, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, out.stderr[-2000:]
    for tag in ("bitmap ok", "event ok", "blocked ok", "trials ok"):
        assert tag in out.stdout, out.stdout
