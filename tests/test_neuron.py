"""LIF dynamics: float oracle vs fixed-point path (paper Eq. 1 + §3.2.1)."""

import jax
import jax.numpy as jnp
import numpy as np

from conftest import given, requires_hypothesis, settings, st

from repro.core.neuron import (FLYWIRE_LIF, FLYWIRE_LIF_1MS, LIFParams,
                               init_state, lif_step, lif_step_fx, fx_to_mv,
                               mv_to_fx)


def test_paper_constants():
    p = FLYWIRE_LIF
    assert p.ref_steps == 22           # 2.2ms / 0.1ms
    assert p.delay_steps == 18         # 1.8ms / 0.1ms
    p1 = FLYWIRE_LIF_1MS
    assert p1.ref_steps == 2           # paper: rounded to 2 steps
    assert p1.delay_steps == 2


def test_subthreshold_decay_no_spike():
    p = FLYWIRE_LIF
    st_ = init_state(4, p)
    g_in = jnp.array([1.0, 2.0, 0.0, 5.0])   # mV, below threshold drive
    s = st_
    for _ in range(50):
        s, spk = lif_step(s, g_in * 0.0, p)
    assert not bool(spk.any())
    assert float(jnp.abs(s.v).max()) < 1e-3


def test_threshold_reset_and_refractory():
    p = LIFParams(dt=1.0, tau_ref=3.0)
    s = init_state(1, p)
    drive = jnp.array([30.0])          # strong sustained drive
    spiked = False
    for _ in range(20):                # v integrates g over tau_m
        s, spk = lif_step(s, drive, p)
        if bool(spk[0]):
            spiked = True
            break
    assert spiked
    assert float(s.v[0]) == p.v_r
    assert float(s.g[0]) == 0.0
    assert int(s.refrac[0]) == p.ref_steps
    # refractory: ignores input
    s2, spk2 = lif_step(s, drive, p)
    assert not bool(spk2[0])
    assert float(s2.g[0]) == 0.0


def test_fixed_point_tracks_float_subthreshold():
    """Below threshold the Q19.12 path tracks the float ODE to within a
    few fixed-point ulps — trajectory-level agreement."""
    p = FLYWIRE_LIF
    n = 64
    rng = np.random.default_rng(0)
    sf = init_state(n, p)
    sx = init_state(n, p, fixed_point=True)
    for step in range(200):
        # sparse event-like drive keeps the trajectory subthreshold
        events = rng.random(n) < 0.02
        g_units = jnp.asarray(events * rng.integers(1, 10, n), jnp.int32)
        g_mv = g_units.astype(jnp.float32) * p.w_scale
        sf, spk_f = lif_step(sf, g_mv, p)
        sx, spk_x = lif_step_fx(sx, g_units, p)
        assert not bool(spk_f.any()) and not bool(spk_x.any())
        v_err = float(jnp.abs(fx_to_mv(sx.v, p) - sf.v).max())
        assert v_err < 0.05, (step, v_err)


def test_fixed_point_spike_statistics_match():
    """With spiking drive, exact spike-for-spike equality is not expected
    (the paper validates statistically); spike *counts* must agree
    closely."""
    p = FLYWIRE_LIF
    n = 128
    rng = np.random.default_rng(1)
    sf = init_state(n, p)
    sx = init_state(n, p, fixed_point=True)
    cf = cx = 0
    for step in range(500):
        g_units = jnp.asarray(rng.integers(0, 40, n), jnp.int32)
        g_mv = g_units.astype(jnp.float32) * p.w_scale
        sf, spk_f = lif_step(sf, g_mv, p)
        sx, spk_x = lif_step_fx(sx, g_units, p)
        cf += int(spk_f.sum())
        cx += int(spk_x.sum())
    assert cf > 0
    assert abs(cf - cx) / cf < 0.02, (cf, cx)


def test_fx_roundtrip():
    p = FLYWIRE_LIF
    x = jnp.array([0.0, 1.0, -3.3, 7.0])
    np.testing.assert_allclose(fx_to_mv(mv_to_fx(x, p), p), x, atol=1e-3)


@requires_hypothesis
@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 1.0), st.integers(1, 50))
def test_refractory_invariant(dt, drive):
    """Property: a neuron never spikes twice within tau_ref."""
    p = LIFParams(dt=dt)
    s = init_state(1, p)
    spikes = []
    for t in range(300):
        s, spk = lif_step(s, jnp.array([float(drive)]), p)
        spikes.append(bool(spk[0]))
    idx = [i for i, x in enumerate(spikes) if x]
    for a, b in zip(idx, idx[1:]):
        assert b - a > p.ref_steps
