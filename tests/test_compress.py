"""Compression schemes (paper §3.2.3, Fig 7): SAR binning, SSD/ELL capping."""

import numpy as np
import pytest

from conftest import given, requires_hypothesis, settings, st

from repro.core import (SimConfig, build_binned, build_ell,
                        compression_report, effective_fan_in_sar, get_engine,
                        quantize_weights, synthetic_flywire)
from repro.core.engine import build_synapses


@pytest.fixture(scope="module")
def net():
    return synthetic_flywire(n=2000, target_synapses=60_000, seed=4)


def test_quantize_caps_to_9bit_range(net):
    wq = quantize_weights(net.in_weights, 9)
    assert wq.max() <= 255 and wq.min() >= -256
    # paper: only a tiny fraction of weights get capped
    frac = np.mean((net.in_weights > 255) | (net.in_weights < -256))
    assert frac < 0.01


def test_sar_effective_fan_in_bound(net):
    """Paper: SAR eff fan-in <= #unique quantized weights <= 2^bits;
    measured max 165 vs raw 10,356 at full scale."""
    eff = effective_fan_in_sar(net, 9)
    assert eff.max() <= 512
    assert eff.max() < net.fan_in.max()
    # exact: eff fan-in == number of unique quantized weights per target
    wq = quantize_weights(net.in_weights, 9)
    for t in [0, 7, 100, int(np.argmax(net.fan_in))]:
        s, e = net.in_indptr[t], net.in_indptr[t + 1]
        assert eff[t] == len(np.unique(wq[s:e]))


def test_compression_report_ratios(net):
    rep = compression_report(net)
    assert rep["sar_memory_ratio"] < 1.0       # always compresses
    assert rep["sar_max_eff_fan_in"] <= rep["sar_theoretical_max"]


def test_binned_delivery_equals_csr_on_quantized(net):
    """SAR bin-compressed delivery must be *exact* vs flat delivery of the
    quantized weights — it is a storage change, not an approximation."""
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    spk = jnp.asarray(rng.random(net.n) < 0.05)
    cfg_b = SimConfig(engine="binned", quantize_bits=9)
    cfg_c = SimConfig(engine="csr", quantize_bits=9)
    syn_b = build_synapses(net, cfg_b)
    syn_c = build_synapses(net, cfg_c)
    gb = np.asarray(get_engine("binned").deliver(syn_b, spk, cfg_b)[0])
    gc = np.asarray(get_engine("csr").deliver(syn_c, spk, cfg_c)[0])
    np.testing.assert_allclose(gb, gc, atol=1e-4)


def test_ell_cap_rescales_weights(net):
    """Paper §3.2.4: fan-in cap via sampling + weight rescaling preserves
    expected drive."""
    cap = 32
    ell = build_ell(net, width_cap=cap, seed=1)
    assert ell.idx.shape[1] <= max(cap, 8)
    capped_targets = np.flatnonzero(net.fan_in > ell.width)
    assert ell.n_capped == len(capped_targets)
    if len(capped_targets):
        t = capped_targets[0]
        s, e = net.in_indptr[t], net.in_indptr[t + 1]
        raw_sum = float(net.in_weights[s:e].sum())
        ell_sum = float(ell.weight[t].sum())
        # expected drive preserved within sampling error
        assert abs(ell_sum - raw_sum) / (abs(raw_sum) + 1e-9) < 0.75


def test_binned_memory_smaller_than_flat(net):
    bf = build_binned(net, bits=9)
    flat_entries = 2 * net.nnz                      # (src, w) per synapse
    binned_entries = bf.nnz + bf.bin_weight.size    # membership + bins
    # SAR must reduce per-synapse weight storage: nnz weights -> bins
    assert bf.bin_weight.shape[1] <= 512


@requires_hypothesis
@settings(max_examples=30, deadline=None)
@given(st.integers(2, 16), st.integers(0, 1000))
def test_quantize_idempotent_and_bounded(bits, seed):
    rng = np.random.default_rng(seed)
    w = rng.integers(-3000, 3000, 200)
    q = quantize_weights(w, bits)
    lo, hi = -(1 << (bits - 1)), (1 << (bits - 1)) - 1
    assert q.min() >= lo and q.max() <= hi
    np.testing.assert_array_equal(quantize_weights(q, bits), q)
    # values already in range are untouched
    inr = (w >= lo) & (w <= hi)
    np.testing.assert_array_equal(q[inr], w[inr])
