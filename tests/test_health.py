"""Resilience layer (PR 6 acceptance): chunked supervised scans,
in-scan health sentinels, checkpoint/resume, and fault-injected recovery.

Pins: (a) ``chunk_steps`` is bit-neutral — chunked == monolithic scan,
bitwise, on float32 AND Q19.12, monolithic and distributed (P=4 emulate);
(b) a killed run resumed from its checkpoints reproduces the
uninterrupted run's counts/raster/records bit-for-bit; (c) poison (NaN)
raises :class:`SimulationHealthError` naming the step and counter; (d) a
drop-rate breach under ``run_resilient`` escalates capacity and converges
to a lossless run bit-equal to an amply-provisioned reference; (e) an
injected partition failure (``faulty`` exchange scheme) is detected and
recovered bit-identically; (f) the checkpoint satellites — dtype-checked
restore, joinable async saves — and the non-finite-masked parity
statistic; (g) supervision backoff — jittered-exponential, capped delays
between restarts/escalations, surfaced as ``backoff_s`` on the telemetry
events, with ``backoff=None`` restoring immediate retry.
"""

import dataclasses
import random
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import (CapacityConfig, FaultSpec, HealthConfig, SimConfig,
                        SimulationHealthError, configure_faulty, parity,
                        run_resilient, simulate, synthetic_flywire)
from repro.core.health import BackoffPolicy
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.exchange.faulty import ExchangeFault
from repro.core.health import health_step_stats
from repro.core.neuron import LIFState
from repro.core.partition import even_partition
from repro.exp import ProbeSpec, StepCurrent, per_neuron


@pytest.fixture(scope="module")
def setup():
    c = synthetic_flywire(n=400, target_synapses=8_000, seed=0)
    sugar = np.arange(80)
    d = build_dcsr(c, even_partition(c, 4))
    return c, sugar, d


PROBES = ProbeSpec(raster=True, pop_rate=True)


def _run(c, cfg, t, sugar, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return simulate(c, cfg, t, sugar_neurons=sugar, seed=3,
                        probes=PROBES, **kw)


def _run_dist(d, dcfg, t, sugar, **kw):
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        return simulate_distributed(d, dcfg, t, sugar_neurons=sugar, seed=3,
                                    emulate=True, probes=PROBES, **kw)


def _assert_bitwise(a, b):
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert np.array_equal(np.asarray(a.raster), np.asarray(b.raster))
    for k in a.records:
        assert np.array_equal(np.asarray(a.records[k]),
                              np.asarray(b.records[k])), k
    assert np.array_equal(np.asarray(a.state.v), np.asarray(b.state.v))
    assert int(np.asarray(a.dropped).sum()) == int(np.asarray(b.dropped).sum())


# --------------------------------------------------------------------------
# (a) chunking is bit-neutral
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine,fx", [("csr", False), ("event", False),
                                       ("event", True)])
def test_chunked_bit_identity_monolithic(setup, engine, fx):
    """ceil(T/K) reuses of one K-step program == the monolithic scan,
    bitwise, including a ragged tail chunk (K does not divide T)."""
    c, sugar, _ = setup
    cfg = SimConfig(engine=engine, fixed_point=fx)
    ref = _run(c, cfg, 50, sugar)
    chk = _run(c, cfg, 50, sugar, chunk_steps=16)     # 16+16+16+2
    _assert_bitwise(ref, chk)


def test_chunked_bit_identity_distributed(setup):
    c, sugar, d = setup
    dcfg = DistConfig(sim=SimConfig(engine="event"), scheme="event")
    ref = _run_dist(d, dcfg, 50, sugar)
    chk = _run_dist(d, dcfg, 50, sugar, chunk_steps=16)
    _assert_bitwise(ref, chk)


def test_chunked_rejects_trials(setup):
    c, sugar, d = setup
    from repro.exp import run_dist_trials
    from repro.core.distributed import _run_partitioned
    dcfg = DistConfig(sim=SimConfig(engine="event"), scheme="event")
    with pytest.raises(ValueError, match="trial-batched"):
        _run_partitioned(d, dcfg, 10, jnp.zeros((4, 2, 2), jnp.uint32),
                         None, None, None, None, True, trials=True,
                         chunk_steps=5)


# --------------------------------------------------------------------------
# sentinels
# --------------------------------------------------------------------------

def test_health_step_stats_counts_nonfinite():
    sim = SimConfig(health=HealthConfig())
    v = jnp.array([0.0, jnp.nan, jnp.inf, 1.0])
    g = jnp.array([0.0, 0.0, 0.0, -jnp.inf])
    lif = LIFState(v=v, g=g, refrac=jnp.zeros(4, jnp.int32))
    assert int(health_step_stats(lif, sim)["h_nonfinite"]) == 3
    # disabled -> no counters, no pytree change
    assert health_step_stats(lif, SimConfig()) == {}


def test_health_step_stats_counts_saturation():
    sim = SimConfig(fixed_point=True, health=HealthConfig(sat_margin_bits=2))
    big = np.int32(1 << 29)
    v = jnp.array([0, big, -big, np.int32(-(2 ** 31))], jnp.int32)
    g = jnp.zeros(4, jnp.int32)
    lif = LIFState(v=v, g=g, refrac=jnp.zeros(4, jnp.int32))
    # int32 min must count (no abs-overflow wraparound)
    assert int(health_step_stats(lif, sim)["h_saturated"]) == 3


def test_stats_surface_on_results(setup):
    c, sugar, d = setup
    cfg = SimConfig(engine="event", health=HealthConfig())
    r = _run(c, cfg, 20, sugar, chunk_steps=10)
    assert int(r.stats["h_nonfinite"]) == 0
    dcfg = DistConfig(sim=cfg, scheme="event")
    rd = _run_dist(d, dcfg, 20, sugar, chunk_steps=10)
    assert int(np.asarray(rd.stats["h_nonfinite"]).sum()) == 0


# --------------------------------------------------------------------------
# (c) poison raises, naming step and counter
# --------------------------------------------------------------------------

def test_nan_poison_raises_named(setup):
    c, _, _ = setup
    cfg = SimConfig(engine="csr", health=HealthConfig())
    # NaN drive from step 0 (NaN * gate stays NaN — exactly the silent
    # poison the sentinels exist for)
    poison = StepCurrent(per_neuron([0], np.nan, c.n), target="v")
    with pytest.raises(SimulationHealthError, match="nonfinite") as ei:
        simulate(c, cfg, 40, stimulus=poison, chunk_steps=10)
    # detected at the first chunk boundary
    assert ei.value.kind == "nonfinite"
    assert ei.value.step == 10
    assert ei.value.value > 0


def test_rate_envelope_breach(setup):
    c, sugar, _ = setup
    cfg = SimConfig(engine="event",
                    health=HealthConfig(rate_hi_hz=1e-6))
    with pytest.raises(SimulationHealthError, match="rate_envelope"):
        _run(c, cfg, 60, sugar, chunk_steps=20)


def test_poison_is_not_recoverable(setup):
    """run_resilient must re-raise poison instead of restart-looping on a
    deterministic corruption."""
    c, _, _ = setup
    cfg = SimConfig(engine="csr", health=HealthConfig())
    poison = StepCurrent(per_neuron([0], np.nan, c.n), t_on=2, target="v")
    calls = []

    def attempt(resume, cap):
        calls.append(resume)
        return simulate(c, cfg, 20, stimulus=poison, chunk_steps=10)

    with pytest.raises(SimulationHealthError, match="nonfinite"):
        run_resilient(attempt)
    assert len(calls) == 1


# --------------------------------------------------------------------------
# (b) kill-and-resume bit-identity
# --------------------------------------------------------------------------

@pytest.mark.parametrize("async_save", [False, True])
def test_kill_and_resume_bit_identity(setup, tmp_path, async_save):
    c, sugar, _ = setup
    cfg = SimConfig(engine="event")
    ref = _run(c, cfg, 50, sugar, chunk_steps=16)
    td = str(tmp_path / "ck")
    # "kill" after 2 chunks: a partial run leaving only its checkpoints
    _run(c, cfg, 32, sugar, chunk_steps=16, checkpoint_dir=td,
         async_checkpoint=async_save)
    res = _run(c, cfg, 50, sugar, chunk_steps=16, checkpoint_dir=td,
               resume=True, async_checkpoint=async_save)
    _assert_bitwise(ref, res)


def test_kill_and_resume_distributed(setup, tmp_path):
    c, sugar, d = setup
    dcfg = DistConfig(sim=SimConfig(engine="event"), scheme="event")
    ref = _run_dist(d, dcfg, 50, sugar, chunk_steps=16)
    td = str(tmp_path / "ck")
    _run_dist(d, dcfg, 32, sugar, chunk_steps=16, checkpoint_dir=td)
    res = _run_dist(d, dcfg, 50, sugar, chunk_steps=16, checkpoint_dir=td,
                    resume=True)
    _assert_bitwise(ref, res)


def test_resume_q19_12_dtype_guard(setup, tmp_path):
    """A Q19.12 checkpoint restored into a float-path template must raise,
    not silently cast (the satellite bugfix, end to end)."""
    c, sugar, _ = setup
    td = str(tmp_path / "ck")
    _run(c, SimConfig(engine="event", fixed_point=True), 32, sugar,
         chunk_steps=16, checkpoint_dir=td)
    with pytest.raises(ValueError, match="dtype mismatch"):
        _run(c, SimConfig(engine="event", fixed_point=False), 50, sugar,
             chunk_steps=16, checkpoint_dir=td, resume=True)


# --------------------------------------------------------------------------
# (d) drop-rate breach -> capacity escalation -> lossless convergence
# --------------------------------------------------------------------------

def test_drop_rate_escalation_converges_lossless(setup, tmp_path):
    c, sugar, _ = setup
    ample = SimConfig(engine="event",
                      capacity=CapacityConfig(512, 65_536))
    ref = _run(c, ample, 80, sugar)
    assert int(ref.dropped) == 0

    tiny = CapacityConfig(spike_capacity=4, syn_budget=64)
    hc = HealthConfig(max_drop_rate=0.0)
    td = str(tmp_path / "ck")
    caps = []

    def attempt(resume, cap):
        cap = cap or tiny
        caps.append(cap)
        cfg = SimConfig(engine="event", capacity=cap, health=hc)
        return _run(c, cfg, 80, sugar, chunk_steps=20, checkpoint_dir=td,
                    resume=resume is not None)

    out = run_resilient(attempt, checkpoint_dir=td, capacity=tiny,
                        max_escalations=10)
    assert len(caps) > 1                      # it did breach and escalate
    assert caps[-1].syn_budget > tiny.syn_budget
    assert int(out.dropped) == 0              # converged lossless
    _assert_bitwise(ref, out)                 # ... and bit-equal to ample


def test_escalation_declined_without_capacity(setup, tmp_path):
    """No base capacity -> the default policy cannot escalate; the breach
    must surface instead of looping."""
    c, sugar, _ = setup
    hc = HealthConfig(max_drop_rate=0.0)
    tiny = CapacityConfig(spike_capacity=4, syn_budget=64)

    def attempt(resume, cap):
        cfg = SimConfig(engine="event", capacity=tiny, health=hc)
        return _run(c, cfg, 80, sugar, chunk_steps=20)

    with pytest.raises(SimulationHealthError, match="drop_rate"):
        run_resilient(attempt)                # capacity=None


# --------------------------------------------------------------------------
# (e) fault injection at the exchange layer
# --------------------------------------------------------------------------

def test_faulty_partition_failure_recovered(setup, tmp_path):
    c, sugar, d = setup
    clean = DistConfig(sim=SimConfig(engine="event"), scheme="event")
    ref = _run_dist(d, clean, 50, sugar, chunk_steps=16)

    configure_faulty(inner="event",
                     spec=FaultSpec(partition=1, fail_at=(20,)))
    fcfg = DistConfig(sim=SimConfig(engine="event"), scheme="faulty")
    td = str(tmp_path / "ck")
    attempts = []

    def attempt(resume, cap):
        attempts.append(resume)
        return _run_dist(d, fcfg, 50, sugar, chunk_steps=16,
                         checkpoint_dir=td, resume=resume is not None)

    out = run_resilient(attempt, checkpoint_dir=td)
    assert len(attempts) == 2                 # failed once, recovered once
    assert attempts[1] == 16                  # resumed from the checkpoint
    _assert_bitwise(ref, out)


def test_faulty_failure_exceeds_restarts(setup, tmp_path):
    configure_faulty(inner="event",
                     spec=FaultSpec(partition=0, fail_at=(4, 20, 36)))
    c, sugar, d = setup
    fcfg = DistConfig(sim=SimConfig(engine="event"), scheme="faulty")
    td = str(tmp_path / "ck")

    def attempt(resume, cap):
        return _run_dist(d, fcfg, 50, sugar, chunk_steps=16,
                         checkpoint_dir=td, resume=resume is not None)

    with pytest.raises(ExchangeFault):
        run_resilient(attempt, checkpoint_dir=td, max_restarts=1)


def test_faulty_payload_drop_is_counted(setup):
    """A lost payload is a counted loss: the failed partition's whole
    outgoing fan-out lands in the exact ``dropped`` counter."""
    c, sugar, d = setup
    clean = DistConfig(sim=SimConfig(engine="event"), scheme="event")
    ref = _run_dist(d, clean, 60, sugar)
    configure_faulty(inner="event",
                     spec=FaultSpec(partition=0,
                                    drop_payload_at=tuple(range(20, 50))))
    fcfg = DistConfig(sim=SimConfig(engine="event"), scheme="faulty")
    out = _run_dist(d, fcfg, 60, sugar)
    assert int(out.dropped) > int(ref.dropped)
    assert not np.array_equal(out.counts, ref.counts)


def test_faulty_configure_guards():
    with pytest.raises(ValueError, match="cannot wrap"):
        configure_faulty(inner="faulty")
    with pytest.raises(ValueError, match="cannot wrap"):
        configure_faulty(inner="local")
    configure_faulty()   # reset to clean defaults for other tests


# --------------------------------------------------------------------------
# (f) satellites: parity non-finite masking
# --------------------------------------------------------------------------

def test_parity_masks_nonfinite():
    a = np.array([1.0, 2.0, np.nan, 4.0, np.inf])
    b = np.array([1.0, 2.0, 3.0, np.nan, 5.0])
    s = parity(a, b)
    assert s.n_nonfinite == 3
    assert np.isfinite(s.rmse_hz) and np.isfinite(s.pearson_r)
    assert s.n_active == 2                    # only finite-in-both survive
    assert "nonfinite=3" in s.summary()


def test_parity_finite_behavior_unchanged():
    rng = np.random.default_rng(0)
    a = rng.uniform(0, 20, 200)
    b = a + rng.normal(0, 0.1, 200)
    s = parity(a, b)
    assert s.n_nonfinite == 0
    assert s.n_active == int(((a > 0.5) | (b > 0.5)).sum())
    assert s.rmse_hz < 0.5 and s.pearson_r > 0.99


# --------------------------------------------------------------------------
# (g) supervision backoff
# --------------------------------------------------------------------------

def test_backoff_policy_exponential_capped_deterministic():
    p = BackoffPolicy(base_s=0.1, factor=2.0, cap_s=0.9, jitter=0.0)
    assert [p.delay(a) for a in range(1, 6)] == [
        pytest.approx(0.1), pytest.approx(0.2), pytest.approx(0.4),
        pytest.approx(0.8), pytest.approx(0.9)]        # clamped at cap_s
    # jitter widens around the nominal delay, deterministically per rng seed
    j = BackoffPolicy(base_s=0.1, factor=2.0, cap_s=0.9, jitter=0.5)
    got = [j.delay(2, rng=random.Random(7)) for _ in range(3)]
    assert got[0] == got[1] == got[2]
    assert 0.1 <= got[0] <= 0.3 and got[0] != pytest.approx(0.2)
    assert j.delay(2, rng=random.Random(7)) != j.delay(2, rng=random.Random(8))


def test_run_resilient_backoff_delays_and_events():
    """Crash-looping runs wait out jittered-exponential delays between
    restarts, and each restart/escalation event carries the applied
    ``backoff_s`` so incident timelines show the supervisor's pacing."""
    boom = [3]
    waits, events = [], []

    def attempt(resume, cap):
        if boom[0]:
            boom[0] -= 1
            raise RuntimeError("transient")
        return "ok"

    with obs.telemetry(events.append, validate=True):
        out = run_resilient(
            attempt, max_restarts=3,
            backoff=BackoffPolicy(base_s=0.05, factor=2.0, cap_s=0.08,
                                  jitter=0.0),
            sleep=waits.append)
    assert out == "ok"
    assert waits == [pytest.approx(0.05), pytest.approx(0.08),
                     pytest.approx(0.08)]              # exponential, capped
    restarts = [e for e in events if e["type"] == "restart"]
    assert [r["attempt"] for r in restarts] == [1, 2, 3]
    assert [r["backoff_s"] for r in restarts] == [
        pytest.approx(0.05), pytest.approx(0.08), pytest.approx(0.08)]
    assert all(r["error"] == "RuntimeError" for r in restarts)


def test_run_resilient_backoff_none_is_immediate():
    boom = [2]
    waits = []

    def attempt(resume, cap):
        if boom[0]:
            boom[0] -= 1
            raise RuntimeError("transient")
        return "ok"

    assert run_resilient(attempt, backoff=None,
                         sleep=waits.append) == "ok"
    assert waits == []


def test_run_resilient_escalation_event_carries_backoff(setup, tmp_path):
    """Drop-rate escalation paces its retries through the same policy and
    stamps the chosen delay on the ``escalation`` event."""
    c, sugar, _ = setup
    hc = HealthConfig(max_drop_rate=0.0)
    tiny = CapacityConfig(spike_capacity=4, syn_budget=64)
    waits, events = [], []

    def attempt(resume, cap):
        cfg = SimConfig(engine="event", capacity=cap or tiny, health=hc)
        return _run(c, cfg, 80, sugar, chunk_steps=20)

    with obs.telemetry(events.append, validate=True):
        out = run_resilient(attempt, checkpoint_dir=str(tmp_path / "ck"),
                            capacity=tiny, max_escalations=10,
                            backoff=BackoffPolicy(base_s=0.01, factor=2.0,
                                                  cap_s=0.02, jitter=0.0),
                            sleep=waits.append)
    assert int(out.dropped) == 0
    esc = [e for e in events if e["type"] == "escalation"]
    assert esc and all(e["kind"] == "drop_rate" for e in esc)
    assert [e["backoff_s"] for e in esc] == [pytest.approx(w) for w in waits]
    assert waits[0] == pytest.approx(0.01)
    assert all(w <= 0.02 + 1e-9 for w in waits)
