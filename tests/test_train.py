"""Training substrate: optimizer, microbatching, compression, checkpoints."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import (AdamW, compress_int8, cosine_schedule,
                         decompress_int8, error_feedback_update)
from repro.train import (latest_step, make_train_step, restore_checkpoint,
                         save_checkpoint)
from repro.train.train_step import init_train_state


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    ds = SyntheticLM(vocab=cfg.vocab, seq=32, global_batch=8)
    return cfg, params, ds


def test_loss_decreases(setup):
    cfg, params, ds = setup
    opt = AdamW(lr=cosine_schedule(3e-3, 5, 60))
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    first = last = None
    for i in range(25):
        state, m = step(state, ds.batch_at(i))
        if first is None:
            first = float(m["loss"])
        last = float(m["loss"])
    assert last < first - 0.3


def test_microbatching_matches_full_batch(setup):
    """Gradient accumulation must be loss/grad-equivalent to one batch."""
    cfg, params, ds = setup
    opt = AdamW(lr=1e-3)
    batch = ds.batch_at(0)
    s1 = init_train_state(params, opt)
    s2 = init_train_state(params, opt)
    step1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    step4 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    s1, m1 = step1(s1, batch)
    s2, m4 = step4(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-4)
    # updated params agree to accumulation-order tolerance
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_int8_compression_roundtrip():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 0.1, (128, 64)), jnp.float32)
    q, s = compress_int8(x)
    assert q.dtype == jnp.int8
    err = np.abs(np.asarray(decompress_int8(q, s) - x)).max()
    assert err <= float(s) * 0.5 + 1e-9          # half-ulp of the scale


def test_error_feedback_accumulates():
    """Residuals carry the quantization error to the next step: the sum of
    transmitted values converges to the sum of true gradients."""
    rng = np.random.default_rng(1)
    true = [jnp.asarray(rng.normal(0, 1e-4, (64,)), jnp.float32)
            for _ in range(50)]
    residual = jnp.zeros((64,), jnp.float32)
    sent = jnp.zeros((64,), jnp.float32)
    for g in true:
        g_hat, residual = error_feedback_update(g, residual)
        sent = sent + g_hat
    total = sum(true)
    np.testing.assert_allclose(np.asarray(sent + residual),
                               np.asarray(sum(true)), atol=1e-6)
    # without error feedback tiny gradients would all quantize to ~0
    assert float(jnp.abs(sent).sum()) > 0.1 * float(jnp.abs(total).sum())


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, params, ds = setup
    opt = AdamW(lr=1e-3)
    state = init_train_state(params, opt)
    step = jax.jit(make_train_step(cfg, opt))
    for i in range(3):
        state, _ = step(state, ds.batch_at(i))
    save_checkpoint(str(tmp_path), 3, state, metadata={"arch": cfg.name})
    assert latest_step(str(tmp_path)) == 3
    target = jax.eval_shape(lambda: state)
    restored, meta = restore_checkpoint(str(tmp_path), 3, target)
    assert meta["arch"] == cfg.name
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # training continues identically after restore
    s_cont, m1 = step(state, ds.batch_at(3))
    r_cont, m2 = step(restored, ds.batch_at(3))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-6)


def test_checkpoint_async_and_atomic(tmp_path, setup):
    cfg, params, ds = setup
    opt = AdamW(lr=1e-3)
    state = init_train_state(params, opt)
    t = save_checkpoint(str(tmp_path), 1, state, async_save=True)
    t.join()
    assert latest_step(str(tmp_path)) == 1
    assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))


def test_data_pipeline_determinism():
    """Batches are a pure function of (seed, step) — exact restart."""
    ds = SyntheticLM(vocab=97, seq=16, global_batch=4, seed=5)
    a = ds.batch_at(7)
    b = ds.batch_at(7)
    np.testing.assert_array_equal(np.asarray(a["tokens"]),
                                  np.asarray(b["tokens"]))
    c = ds.batch_at(8)
    assert not np.array_equal(np.asarray(a["tokens"]),
                              np.asarray(c["tokens"]))
    # labels are the shifted stream
    full_a = np.asarray(a["tokens"])[:, 1:]
    np.testing.assert_array_equal(full_a, np.asarray(a["labels"])[:, :-1])


def test_restore_rejects_dtype_mismatch(tmp_path):
    """A Q19.12 int32 leaf restored into a float template must raise, not
    silently cast (the cast would corrupt the fixed-point contract)."""
    tree = {"v": jnp.arange(8, dtype=jnp.int32)}
    save_checkpoint(str(tmp_path), 1, tree)
    good, _ = restore_checkpoint(
        str(tmp_path), 1, {"v": jax.ShapeDtypeStruct((8,), jnp.int32)})
    np.testing.assert_array_equal(np.asarray(good["v"]), np.arange(8))
    with pytest.raises(ValueError, match="dtype mismatch"):
        restore_checkpoint(
            str(tmp_path), 1, {"v": jax.ShapeDtypeStruct((8,), jnp.float32)})


def test_async_save_handle_propagates_errors(tmp_path):
    """join() must re-raise a write-thread failure instead of losing it
    with a daemon thread."""
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("occupied")
    h = save_checkpoint(str(blocker), 1, {"x": jnp.zeros(2)},
                        async_save=True)
    with pytest.raises(OSError):
        h.join()
    assert h.done()


def test_checkpoint_ignores_extra_leaves(tmp_path):
    """Sub-tree restore: checkpoint leaves the target does not reference
    are ignored (the simulation checkpointer restores the carry from a
    {carry, records} checkpoint this way)."""
    save_checkpoint(str(tmp_path), 2,
                    {"a": jnp.ones(3), "b": jnp.zeros(5)})
    out, _ = restore_checkpoint(
        str(tmp_path), 2, {"a": jax.ShapeDtypeStruct((3,), jnp.float32)})
    assert set(out) == {"a"}
