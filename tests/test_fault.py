"""Fault tolerance: straggler detection, failure injection + recovery,
elastic restore onto a different sharding layout."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.models import init_params
from repro.optim import AdamW
from repro.train import (FaultConfig, StragglerDetector, latest_step,
                         make_train_step, restore_checkpoint,
                         save_checkpoint, simulate_failures)
from repro.train.fault import InjectedFailure, run_with_recovery
from repro.train.train_step import init_train_state


def test_straggler_detector_flags_outliers():
    det = StragglerDetector(window=16, z_threshold=3.0)
    rng = np.random.default_rng(0)
    flagged = []
    for i in range(100):
        dt = 0.1 + rng.normal(0, 0.002)
        if i in (50, 80):
            dt = 0.5                      # injected straggler
        if det.observe(i, dt):
            flagged.append(i)
    assert flagged == [50, 80]
    # stragglers don't poison the baseline window
    assert float(np.mean(det.times)) < 0.12


def test_simulate_failures_raises():
    cfg = FaultConfig(fail_at_steps=(3,))
    simulate_failures(2, cfg)
    with pytest.raises(InjectedFailure):
        simulate_failures(3, cfg)


def test_recovery_loop_restarts_from_checkpoint(tmp_path):
    """End-to-end: train, crash at step 5, supervisor restarts from the
    last checkpoint, run completes, loss trajectory continues."""
    cfg = get_config("qwen2.5-14b", smoke=True)
    opt = AdamW(lr=1e-3)
    ds = SyntheticLM(vocab=cfg.vocab, seq=16, global_batch=4)
    step_fn = jax.jit(make_train_step(cfg, opt))
    ckpt = str(tmp_path)
    total_steps = 10
    attempts = []

    def attempt(resume_signal):
        attempts.append(resume_signal)
        start = 0
        state = init_train_state(
            init_params(jax.random.PRNGKey(0), cfg), opt)
        if resume_signal is not None:
            last = latest_step(ckpt)
            state, _ = restore_checkpoint(ckpt, last,
                                          jax.eval_shape(lambda: state))
            start = last
        for i in range(start, total_steps):
            if i == 5 and resume_signal is None:
                raise InjectedFailure("node died")
            state, m = step_fn(state, ds.batch_at(i))
            if (i + 1) % 2 == 0:
                save_checkpoint(ckpt, i + 1, state)
        return total_steps

    final = run_with_recovery(attempt, max_restarts=2)
    assert final == total_steps
    assert len(attempts) == 2              # one crash, one successful resume
    assert latest_step(ckpt) == total_steps


def test_elastic_restore_changes_layout(tmp_path):
    """Restore is layout-agnostic: the checkpoint written from one 'mesh'
    restores onto explicitly different device_put layouts (here: the
    1-device degenerate case exercises the device_put path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    cfg = get_config("qwen2.5-14b", smoke=True)
    opt = AdamW(lr=1e-3)
    state = init_train_state(init_params(jax.random.PRNGKey(0), cfg), opt)
    save_checkpoint(str(tmp_path), 1, state)
    mesh = jax.make_mesh((1,), ("data",))
    shardings = jax.tree.map(lambda _: NamedSharding(mesh, P()), state)
    restored, _ = restore_checkpoint(str(tmp_path), 1,
                                     jax.eval_shape(lambda: state),
                                     shardings)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_recovery_with_explicit_checkpoint_dir(tmp_path):
    """With checkpoint_dir, the supervisor hands run_fn the explicit
    latest_step instead of the legacy -1 sentinel."""
    ckpt = str(tmp_path)
    save_checkpoint(ckpt, 6, {"x": jnp.zeros(2)})
    signals = []

    def attempt(resume_signal):
        signals.append(resume_signal)
        if len(signals) == 1:
            raise InjectedFailure("node died")
        return 10

    assert run_with_recovery(attempt, max_restarts=2,
                             checkpoint_dir=ckpt) == 10
    assert signals == [None, 6]


def test_recovery_cold_restart_signal(tmp_path):
    """No checkpoint on disk yet -> the restart signal stays None (a cold
    restart), never -1."""
    signals = []

    def attempt(resume_signal):
        signals.append(resume_signal)
        if len(signals) == 1:
            raise InjectedFailure("early death")
        return 1

    run_with_recovery(attempt, max_restarts=2,
                      checkpoint_dir=str(tmp_path / "empty"))
    assert signals == [None, None]
