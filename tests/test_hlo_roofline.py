"""HLO analyzer (trip-count-aware) + roofline arithmetic."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo import analyze_hlo, parse_collectives
from repro.launch.roofline import analyse_record, model_flops

FAKE_HLO = """
HloModule jit_step

ENTRY %main.1 (p0: f32[64,128], x: bf16[1024], y: f32[64,32], z: f32[128], w: f32[8,4], a: f32[16,64], b: f32[64,128]) -> f32[16,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  %x = bf16[1024]{0} parameter(1)
  %y = f32[64,32]{1,0} parameter(2)
  %z = f32[128]{0} parameter(3)
  %w = f32[8,4]{1,0} parameter(4)
  %a = f32[16,64]{1,0} parameter(5)
  %b = f32[64,128]{1,0} parameter(6)
  %ag = f32[256,128]{1,0} all-gather(%p0), channel_id=1, replica_groups=[4,4]<=[4,4]T(1,0), dimensions={0}
  %ar = bf16[1024]{0} all-reduce(%x), channel_id=2, replica_groups=[2,8]<=[16]
  %rs = f32[64,32]{1,0} reduce-scatter(%y), channel_id=3, replica_groups=[1,16]<=[16], dimensions={0}
  %cp = f32[128]{0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
  %ag2 = (f32[8,4]{1,0}, f32[32,4]{1,0}) all-gather-start(%w), channel_id=5, replica_groups=[4,4]<=[16], dimensions={0}
  ROOT %dot.1 = f32[16,128]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_parse_collectives_counts_and_bytes():
    st = parse_collectives(FAKE_HLO)
    assert st.per_op_count == {"all-gather": 2, "all-reduce": 1,
                               "reduce-scatter": 1, "collective-permute": 1}
    ag1 = 256 * 128 * 4 * 3 / 4            # (g-1)/g x result
    ar = 2 * (7 / 8) * 1024 * 2            # ring all-reduce
    rs = 15 * 64 * 32 * 4                  # (g-1) x scattered result
    cp = 128 * 4
    ag2 = (3 / 4) * 32 * 4 * 4             # async tuple: result is last
    np.testing.assert_allclose(st.link_bytes, ag1 + ar + rs + cp + ag2)


def test_analyze_hlo_dot_flops():
    cost = analyze_hlo(FAKE_HLO)
    np.testing.assert_allclose(cost.flops, 2 * 16 * 128 * 64)


def test_analyze_hlo_trip_count_multiplication():
    """The reason this analyzer exists: XLA cost_analysis counts while
    bodies once; ours multiplies by trip counts (nested)."""
    def f(x, w):
        def outer(c, _):
            def body(c2, _):
                return jnp.tanh(c2 @ w), None
            y, _ = jax.lax.scan(body, c, None, length=8)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y.sum()

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    compiled = jax.jit(f).lower(x, w).compile()
    cost = analyze_hlo(compiled.as_text())
    np.testing.assert_allclose(cost.flops, 4 * 8 * 2 * 128 ** 3)
    trips = sorted(t for _, t in cost.while_trips)
    assert trips == [4, 8]


def test_roofline_terms_and_dominance():
    # flops_per_device must be >= model_flops / chips for consistency
    # (HLO compute includes everything the model math needs)
    rec = {
        "arch": "qwen2.5-14b", "cell": "train_4k", "mesh": "16x16",
        "n_devices": 256, "kind": "train",
        "meta": {"mesh": {"data": 16, "model": 16}, "microbatches": 8},
        "memory": {"peak_device_bytes": 8 * 2**30},
        "cost": {"flops_per_device": 6e14, "bytes_per_device": 3e11},
        "collectives": {"link_bytes": 2e9},
    }
    out = analyse_record(rec)
    t = out["terms"]
    np.testing.assert_allclose(t["compute_s"], 6e14 / 197e12)
    # memory term comes from the analytic traffic model (not HLO bytes)
    assert 0.1 < t["memory_s"] < 10.0
    np.testing.assert_allclose(t["collective_s"], 2e9 / 50e9)
    np.testing.assert_allclose(t["hlo_bytes_bound_s"], 3e11 / 819e9)
    assert out["dominant"] == "compute"
    assert out["model_flops"] > 0
    assert 0 < out["useful_ratio"] <= 1.0
    np.testing.assert_allclose(out["roofline_frac"], out["useful_ratio"],
                               rtol=1e-6)
    # a bandwidth-bound decode record: memory dominates
    rec2 = {
        "arch": "qwen2.5-14b", "cell": "decode_32k", "mesh": "16x16",
        "n_devices": 256, "kind": "decode",
        "meta": {"mesh": {"data": 16, "model": 16}},
        "memory": {"peak_device_bytes": 8 * 2**30},
        "cost": {"flops_per_device": 1e10, "bytes_per_device": 3e11},
        "collectives": {"link_bytes": 1e7},
    }
    out2 = analyse_record(rec2)
    assert out2["dominant"] == "memory"


def test_model_flops_moe_uses_active_params():
    dense = model_flops("qwen2.5-14b", "train_4k")
    moe = model_flops("grok-1-314b", "train_4k")
    # grok-1 has ~6x the active params of qwen-14b (not 21x total)
    ratio = moe / dense
    assert 4 < ratio < 9, ratio


def test_model_flops_kinds_scale():
    tr = model_flops("qwen2.5-14b", "train_4k")
    pf = model_flops("qwen2.5-14b", "prefill_32k")
    dc = model_flops("qwen2.5-14b", "decode_32k")
    assert tr == 6 / 2 * pf * (256 * 4096) / (32 * 32768)
    assert dc < pf / 1000
