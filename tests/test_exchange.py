"""The unified step core + exchange-scheme registry (PR 4 acceptance).

Pins: (a) the refactor is invisible — ``simulate_distributed(...,
emulate=True)`` is bit-identical to the pre-refactor implementation on the
pinned legacy scenario (golden hashes captured from the old monolithic
distributed step before its deletion); (b) the sharded ``blocked`` scheme
is count-parity with ``event``;
(c) the distributed path has full observability parity with the
monolithic one (probe records, trials batching), and pad neurons never
leak into any record or count; (d) the capacity knobs and legacy
observability aliases are deprecated-but-working shims.
"""

import dataclasses
import hashlib
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from conftest import given, requires_hypothesis, settings, st
from repro.core import (CapacityConfig, SimConfig, available_schemes,
                        get_scheme, simulate, synthetic_flywire)
from repro.core.dcsr import build_dcsr
from repro.core.distributed import DistConfig, simulate_distributed
from repro.core.exchange import build_dist_arrays
from repro.core.partition import even_partition
from repro.exp import (Compose, ProbeSpec, StepCurrent, per_neuron,
                       run_dist_trials)


@pytest.fixture(scope="module")
def setup():
    c = synthetic_flywire(n=1600, target_synapses=48_000, seed=8)
    sugar = np.arange(20)
    d = build_dcsr(c, even_partition(c, 4))
    return c, sugar, d


def _sha(counts) -> str:
    return hashlib.sha256(
        np.ascontiguousarray(counts).tobytes()).hexdigest()[:16]


# --------------------------------------------------------------------------
# Registry + pinned pre-refactor bit-identity
# --------------------------------------------------------------------------

def test_exchange_registry():
    assert {"local", "bitmap", "event", "blocked"} <= set(available_schemes())
    assert get_scheme("event").name == "event"
    with pytest.raises(ValueError, match="unknown exchange scheme"):
        get_scheme("no-such-scheme")
    # the monolithic-only scheme is rejected on the distributed entry point
    c = synthetic_flywire(n=300, target_synapses=3_000, seed=0)
    d = build_dcsr(c, even_partition(c, 2))
    with pytest.raises(ValueError, match="unknown distributed"):
        simulate_distributed(d, DistConfig(sim=SimConfig(), scheme="local"),
                             5, emulate=True)


# Golden values captured from the pre-refactor distributed step (commit
# 7535a45) on the pinned legacy scenario: n=1600/48k syn/seed 8, P=4,
# sugar=arange(20), T=300, seed=3.
LEGACY_GOLDEN = {
    # (fixed_point) -> (counts.sum, dropped, sha256(counts)[:16])
    False: (71, 0, "d61052e7e462f364"),
    True: (43, 0, "afc740145ec1128d"),
}


@pytest.mark.parametrize("scheme", ["bitmap", "event"])
@pytest.mark.parametrize("fx", [False, True])
def test_emulated_distributed_bit_identical_to_pre_refactor(setup, scheme, fx):
    """Acceptance: the unified step core returns bit-identical counts and
    drops to the deleted per-path step body on the pinned legacy
    scenario."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr", fixed_point=fx, poisson_to_v=not fx,
                    quantize_bits=9 if fx else None)
    r = simulate_distributed(d, DistConfig(sim=sim, scheme=scheme), 300,
                             sugar, seed=3, emulate=True)
    want_sum, want_drop, want_sha = LEGACY_GOLDEN[fx]
    assert int(r.counts.sum()) == want_sum
    assert r.dropped == want_drop
    assert _sha(r.counts) == want_sha


def test_overflow_drops_bit_identical_to_pre_refactor(setup):
    """Same pin under capacity starvation: exact drop accounting survived
    the move into the exchange layer."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr", background_rate_hz=300.0)
    r = simulate_distributed(
        d, DistConfig(sim=sim, scheme="event",
                      capacity=CapacityConfig(4, 256, 0)),
        50, sugar, seed=0, emulate=True)
    assert (int(r.counts.sum()), r.dropped) == (1556, 15358)
    assert _sha(r.counts) == "7c5be7664662758f"


# --------------------------------------------------------------------------
# Sharded blocked scheme
# --------------------------------------------------------------------------

def test_blocked_scheme_count_parity_with_event(setup):
    """The ROADMAP item's acceptance: tile-granular delivery over the
    per-partition blk_id remap is a storage change, not an approximation —
    counts are bit-identical to the event scheme (integer weights sum
    exactly in f32)."""
    c, sugar, d = setup
    sim = SimConfig(engine="csr")
    e = simulate_distributed(d, DistConfig(sim=sim, scheme="event"), 200,
                             sugar, seed=3, emulate=True)
    b = simulate_distributed(d, DistConfig(sim=sim, scheme="blocked"), 200,
                             sugar, seed=3, emulate=True)
    np.testing.assert_array_equal(e.counts, b.counts)
    assert b.dropped == 0


def test_blocked_scheme_tile_stats_track_sparsity(setup):
    """tiles_live/tiles_skipped counters: conserved per step (live +
    skipped == stored), and sparser activity skips more tiles."""
    from repro.kernels.spike_prop.ops import build_blocked_sharded
    c, sugar, d = setup
    stored = build_blocked_sharded(d).tiles_stored
    T = 100

    def run(background_hz):
        sim = SimConfig(engine="csr", poisson_rate_hz=0.0,
                        background_rate_hz=background_hz)
        return simulate_distributed(
            d, DistConfig(sim=sim, scheme="blocked"), T, None, seed=0,
            emulate=True)

    quiet, busy = run(2.0), run(80.0)
    for r in (quiet, busy):
        assert int(r.stats["tiles_live"] + r.stats["tiles_skipped"]) \
            == stored * T
    assert int(quiet.stats["tiles_live"]) < int(busy.stats["tiles_live"])


def test_blocked_scheme_quantized_matches_bitmap(setup):
    """Weights quantized by build_dcsr flow identically through the dense
    tiles and the flat in-CSR."""
    c, sugar, _ = setup
    d9 = build_dcsr(c, even_partition(c, 4), quantize_bits=9)
    sim = SimConfig(engine="csr", quantize_bits=9, fixed_point=True,
                    poisson_to_v=False)
    a = simulate_distributed(d9, DistConfig(sim=sim, scheme="bitmap"), 150,
                             sugar, seed=5, emulate=True)
    b = simulate_distributed(d9, DistConfig(sim=sim, scheme="blocked"), 150,
                             sugar, seed=5, emulate=True)
    np.testing.assert_array_equal(a.counts, b.counts)


# --------------------------------------------------------------------------
# Distributed observability parity (satellite: probe records)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fx", [False, True])
@pytest.mark.parametrize("scheme", ["bitmap", "event", "blocked"])
def test_probe_record_parity_monolithic_vs_distributed(setup, scheme, fx):
    """Under a deterministic stimulus the network evolution is identical,
    so every probe record must match the monolithic run after the
    inv_perm mapping: raster and voltage bit-exactly, pop-rate to float
    tolerance, drops exactly."""
    c, _, d = setup
    ids = (3, 100, 777, 1599)
    stim = Compose((StepCurrent(weights=per_neuron(np.arange(40), 90.0, c.n),
                                t_on=5, t_off=60),))
    probes = ProbeSpec(raster=True, voltage=ids, pop_rate=True, drops=True)
    cfg = SimConfig(engine="csr", fixed_point=fx,
                    quantize_bits=9 if fx else None)
    T = 80
    mono = simulate(c, cfg, T, stimulus=stim, probes=probes, seed=0)
    dist = simulate_distributed(d, DistConfig(sim=cfg, scheme=scheme), T,
                                stimulus=stim, probes=probes, seed=0,
                                emulate=True)
    assert int(np.asarray(mono.counts).sum()) > 0
    np.testing.assert_array_equal(np.asarray(mono.counts), dist.counts)
    np.testing.assert_array_equal(np.asarray(mono.raster), dist.raster)
    np.testing.assert_array_equal(np.asarray(mono.records["v"]),
                                  dist.records["v"])
    np.testing.assert_allclose(np.asarray(mono.records["pop_rate_hz"]),
                               dist.records["pop_rate_hz"], rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(mono.records["dropped"]),
                                  dist.records["dropped"])
    # full SimResult shape: final LIF state mapped back per neuron
    np.testing.assert_array_equal(np.asarray(mono.state.v),
                                  np.asarray(dist.state.v))


def test_dist_voltage_probe_out_of_range(setup):
    c, _, d = setup
    with pytest.raises(ValueError, match="out of range"):
        simulate_distributed(d, DistConfig(sim=SimConfig(engine="csr")), 5,
                             emulate=True,
                             probes=ProbeSpec(voltage=(c.n,)))


def test_dist_trials_match_sequential(setup):
    """run_dist_trials == the same seeds run one by one (emulated)."""
    c, sugar, d = setup
    cfg = DistConfig(sim=SimConfig(engine="csr", background_rate_hz=10.0),
                     scheme="event")
    seeds = [3, 11, 42]
    batch = run_dist_trials(d, cfg, 120, sugar, seeds=seeds, emulate=True,
                            probes=ProbeSpec(raster=True))
    assert batch.counts.shape == (3, c.n)
    assert batch.records["raster"].shape == (3, 120, c.n)
    for i, s in enumerate(seeds):
        one = simulate_distributed(d, cfg, 120, sugar, seed=s, emulate=True)
        np.testing.assert_array_equal(batch.counts[i], one.counts)
        assert int(batch.dropped[i]) == one.dropped
        np.testing.assert_array_equal(batch.state.v[i],
                                      np.asarray(one.state.v))
    np.testing.assert_array_equal(
        batch.records["raster"].sum(axis=1), batch.counts)


# --------------------------------------------------------------------------
# Pad-neuron property (satellite: distributed observability tests)
# --------------------------------------------------------------------------

@requires_hypothesis
@settings(max_examples=6, deadline=None)
@given(n=st.sampled_from([301, 640, 1100]), n_parts=st.sampled_from([2, 3, 5]),
       seed=st.integers(min_value=0, max_value=3))
def test_pad_neurons_never_in_any_record_or_count(n, n_parts, seed):
    """Property: whatever the (n, P, seed) geometry — including partition
    sizes that force heavy padding — pad slots never spike, never count,
    and never reach any probe record."""
    from repro.core.distributed import _run_partitioned
    import jax
    c = synthetic_flywire(n=n, target_synapses=6 * n, seed=seed)
    d = build_dcsr(c, even_partition(c, n_parts))
    cfg = DistConfig(sim=SimConfig(engine="csr", background_rate_hz=200.0),
                     scheme="event")
    keys = jax.random.split(jax.random.PRNGKey(seed), d.n_parts)
    out, records, _probes, _owner = _run_partitioned(
        d, cfg, 25, keys, None, None, ProbeSpec(raster=True), None,
        emulate=True, trials=False)
    pad = d.inv_perm.reshape(d.n_parts, d.part_size) < 0
    counts = np.asarray(out.counts)              # [P, U]
    raster = np.asarray(records["raster"])       # [P, T, U]
    assert counts.sum() > 0                      # the drive elicits spikes
    assert counts[pad].sum() == 0
    assert not raster.transpose(0, 2, 1)[pad].any()
    # and the mapped-back result carries every real spike, none invented
    res = simulate_distributed(d, cfg, 25, None, seed=seed, emulate=True,
                               probes=ProbeSpec(raster=True))
    assert res.counts.sum() == counts.sum()
    assert res.raster.sum() == raster.sum()


# --------------------------------------------------------------------------
# build_dist_arrays: vectorized + memoized (satellite)
# --------------------------------------------------------------------------

def _ref_dist_arrays(d):
    """The pre-vectorization per-partition loop, kept as the oracle."""
    P_, U, S = d.n_parts, d.part_size, d.s_max
    n_glob = P_ * U
    out_indptr = np.zeros((P_, n_glob + 1), dtype=np.int32)
    out_tgt = np.full((P_, S), U, dtype=np.int32)
    out_w = np.zeros((P_, S), dtype=np.float32)
    for p in range(P_):
        valid = d.syn_src[p] < n_glob
        src = d.syn_src[p][valid]
        order = np.argsort(src, kind="stable")
        m = len(src)
        out_tgt[p, :m] = d.syn_tgt_local[p][valid][order]
        out_w[p, :m] = d.syn_w[p][valid][order]
        counts = np.bincount(src[order], minlength=n_glob)
        np.cumsum(counts, out=out_indptr[p, 1:])
    gfo = np.diff(out_indptr, axis=1).sum(axis=0).astype(np.int32)
    return out_indptr, out_tgt, out_w, gfo.reshape(P_, U)


def test_build_dist_arrays_matches_reference_loop(setup):
    c, _, d = setup
    arrs = build_dist_arrays(d)
    indptr, tgt, w, gfo = _ref_dist_arrays(d)
    np.testing.assert_array_equal(np.asarray(arrs.out_indptr), indptr)
    np.testing.assert_array_equal(np.asarray(arrs.out_tgt), tgt)
    np.testing.assert_array_equal(np.asarray(arrs.out_w), w)
    np.testing.assert_array_equal(np.asarray(arrs.src_gfo), gfo)
    np.testing.assert_array_equal(
        np.asarray(arrs.pad_mask), d.inv_perm.reshape(d.n_parts, -1) >= 0)


def test_build_dist_arrays_memoized_on_dcsr(setup):
    c, _, d = setup
    assert build_dist_arrays(d) is build_dist_arrays(d)
    # a different snapshot gets its own entry
    d2 = build_dcsr(c, even_partition(c, 2))
    assert build_dist_arrays(d2) is not build_dist_arrays(d)


# --------------------------------------------------------------------------
# Capacity dedup + deprecation shims (satellites)
# --------------------------------------------------------------------------

def test_capacity_config_routes_both_configs():
    cap = CapacityConfig(spike_capacity=33, syn_budget=4444,
                         block_capacity=7)
    sim = SimConfig(engine="event", **cap.as_config_kwargs())
    assert sim.capacity == cap
    # replace() with a new capacity must take effect (no stale-mirror
    # clobber) and stay silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        swapped = dataclasses.replace(
            sim, capacity=CapacityConfig(spike_capacity=1024))
    assert swapped.capacity.spike_capacity == 1024
    dc = DistConfig(sim=sim, capacity=cap)
    assert dc.capacity == cap
    # historical defaults preserved per config
    assert SimConfig().capacity == CapacityConfig(512, 65_536, 0)
    assert DistConfig(sim=SimConfig()).capacity == CapacityConfig(256, 32_768, 0)


def test_legacy_capacity_fields_warn_and_still_work():
    with pytest.warns(DeprecationWarning, match="syn_budget"):
        cfg = SimConfig(engine="event", syn_budget=256)
    assert cfg.capacity.syn_budget == 256
    assert cfg.capacity.spike_capacity == 512     # untouched default
    with pytest.warns(DeprecationWarning, match="spike_capacity"):
        dc = DistConfig(sim=SimConfig(), spike_capacity=4, syn_budget=99)
    assert (dc.capacity.spike_capacity, dc.capacity.syn_budget) == (4, 99)
    # replace() round-trips silently (the shims are consumed at init)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg2 = dataclasses.replace(cfg, background_rate_hz=5.0)
        # and an explicitly replaced capacity wins even on a config that
        # was originally built through a legacy shim
        cfg3 = dataclasses.replace(
            cfg, capacity=CapacityConfig(syn_budget=9999))
    assert cfg2.capacity == cfg.capacity
    assert cfg3.capacity.syn_budget == 9999


def test_legacy_observability_aliases_warn():
    c = synthetic_flywire(n=300, target_synapses=3_000, seed=1)
    with pytest.warns(DeprecationWarning, match="collect_raster"):
        cfg = SimConfig(engine="csr", collect_raster=True)
    with pytest.warns(DeprecationWarning, match="sugar_neurons"):
        simulate(c, SimConfig(engine="csr"), 5, np.arange(5))
    # the aliases still behave
    res = simulate(c, cfg, 5, stimulus=Compose(()))
    assert res.raster is not None and res.raster.shape == (5, c.n)
