"""Hierarchical active-set compaction + bounded ragged gather: the shared
sparse-path primitives (repro.core.compaction) against numpy references."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.compaction import (BLOCK, active_fanout_total,
                                   derived_block_capacity, n_blocks,
                                   ragged_slots, slot_owner,
                                   two_level_active)


def np_two_level(spikes: np.ndarray, cap: int, bcap: int,
                 block: int = BLOCK) -> np.ndarray:
    """Reference semantics: first ``bcap`` active blocks by id, first
    ``cap`` active neurons by id within them, fill = n."""
    n = len(spikes)
    ids = np.flatnonzero(spikes)
    kept_blocks = np.unique(ids // block)[:bcap]
    kept = ids[np.isin(ids // block, kept_blocks)][:cap]
    out = np.full(cap, n, np.int64)
    out[:len(kept)] = kept
    return out


@pytest.mark.parametrize("n", [100, 128, 1000, 5000])
@pytest.mark.parametrize("density", [0.0, 0.002, 0.05])
def test_two_level_matches_flat_where_with_ample_capacity(n, density):
    rng = np.random.default_rng(n + int(density * 1000))
    spikes = rng.random(n) < density
    cap = max(8, int(spikes.sum()) + 4)
    bcap = derived_block_capacity(n, cap)
    got = np.asarray(two_level_active(jnp.asarray(spikes), cap, bcap))
    want = np.asarray(jnp.where(jnp.asarray(spikes), size=cap,
                                fill_value=n)[0])
    np.testing.assert_array_equal(got, want)
    np.testing.assert_array_equal(got, np_two_level(spikes, cap, bcap))


@pytest.mark.parametrize("cap,bcap", [(4, 64), (64, 2), (3, 1), (6, 3)])
def test_two_level_overflow_keeps_hierarchical_prefix(cap, bcap):
    """Under overflow the kept set is the documented deterministic prefix —
    what the exact drop accounting and the numpy references rely on."""
    n = 2000
    rng = np.random.default_rng(7)
    spikes = rng.random(n) < 0.02   # ~40 spikes over ~16 blocks
    got = np.asarray(two_level_active(jnp.asarray(spikes), cap, bcap))
    np.testing.assert_array_equal(got, np_two_level(spikes, cap, bcap))


def test_two_level_empty_and_full():
    n = 300
    cap, bcap = 8, derived_block_capacity(n, 8)
    got = np.asarray(two_level_active(jnp.zeros(n, bool), cap, bcap))
    np.testing.assert_array_equal(got, np.full(cap, n))
    got = np.asarray(two_level_active(jnp.ones(n, bool), cap, bcap))
    np.testing.assert_array_equal(got, np_two_level(np.ones(n, bool), cap,
                                                    bcap))


def test_slot_owner_equals_searchsorted():
    rng = np.random.default_rng(0)
    lens = rng.integers(0, 30, 17)
    seg_end = np.cumsum(lens).astype(np.int32)
    budget = 200
    got = np.asarray(slot_owner(jnp.asarray(seg_end), budget))
    want = np.searchsorted(seg_end, np.arange(budget), side="right")
    np.testing.assert_array_equal(got, want)


def test_ragged_slots_matches_numpy_reference():
    rng = np.random.default_rng(3)
    n, budget = 40, 64
    lens = rng.integers(0, 9, n)
    indptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    nnz = int(indptr[-1])
    ids = np.array([5, 0, 17, n, 39, n, n, 12], np.int32)  # n = invalid
    syn_ix, ok, total = ragged_slots(
        jnp.asarray(ids), jnp.asarray(indptr), budget,
        invalid_from=n, gather_size=nnz)
    flat = np.concatenate([np.arange(indptr[i], indptr[i + 1])
                           for i in ids if i < n] or [np.array([], int)])
    assert int(total) == len(flat)
    keep = flat[:budget]
    got = np.asarray(syn_ix)[np.asarray(ok)]
    np.testing.assert_array_equal(got, keep)
    # starved budget: prefix kept, total still reports the full request
    syn_ix, ok, total = ragged_slots(
        jnp.asarray(ids), jnp.asarray(indptr), 7,
        invalid_from=n, gather_size=nnz)
    assert int(total) == len(flat)
    np.testing.assert_array_equal(np.asarray(syn_ix)[np.asarray(ok)],
                                  flat[:7])


def test_active_fanout_total():
    rng = np.random.default_rng(1)
    lens = rng.integers(0, 50, 200)
    indptr = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    spikes = rng.random(200) < 0.3
    got = int(active_fanout_total(jnp.asarray(spikes), jnp.asarray(indptr)))
    assert got == int(lens[spikes].sum())


def test_block_helpers():
    assert n_blocks(256) == 2 and n_blocks(257) == 3
    assert derived_block_capacity(60_000, 64) == 64       # cap-limited
    assert derived_block_capacity(400, 64) == n_blocks(400)  # block-limited
    assert derived_block_capacity(1, 1) == 1
