"""Serving engine: continuous batching over the prefill/decode API."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_params, prefill
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen2.5-14b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def greedy_reference(params, cfg, prompt, n_new):
    """Authoritative slow path: full forward re-run per generated token."""
    toks = list(prompt)
    out = []
    for _ in range(n_new):
        logits, _ = forward(params, {"tokens": jnp.asarray(toks)[None]}, cfg)
        nxt = int(jnp.argmax(logits[0, -1]))
        out.append(nxt)
        toks.append(nxt)
    return out


def test_engine_matches_full_forward_generation(setup):
    cfg, params = setup
    prompt = np.arange(7) % cfg.vocab
    want = greedy_reference(params, cfg, prompt, 5)
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=64))
    [req] = eng.run([Request(rid=0, prompt=prompt, max_new=5)])
    assert req.out == want


def test_engine_serves_more_requests_than_slots(setup):
    cfg, params = setup
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=3, max_len=64))
    reqs = [Request(rid=i, prompt=(np.arange(4 + i) % cfg.vocab), max_new=6)
            for i in range(7)]
    done = eng.run(list(reqs))
    assert len(done) == 7
    assert all(len(r.out) == 6 for r in done)


def test_engine_stats_counters(setup):
    """Admission/decode accounting flows through the always-on metrics
    registry (no ambient telemetry session needed) and compile-cache
    hits accumulate across the repeated prefill/decode signatures."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=64))
    reqs = [Request(rid=i, prompt=(np.arange(4) % cfg.vocab), max_new=3)
            for i in range(5)]
    # overfill by hand: admissions beyond the 2 slots are rejected
    admitted = [eng.add_request(r) for r in reqs]
    assert admitted == [True, True, False, False, False]
    s = eng.stats()
    assert s["admitted"] == 2 and s["rejected"] == 3
    assert s["slots_live"] == 2 and s["slots_free"] == 0
    # the run loop drains everything; counters keep accumulating.  run()
    # returns every request that finished during the call — including the
    # pair admitted by hand above, which the old workload-rescan loop
    # silently omitted.
    done = eng.run([r for r, ok in zip(reqs, admitted) if not ok])
    assert len(done) == 5
    assert all(r.done for r in reqs)   # pre-admitted pair finished too
    s = eng.stats()
    assert s["admitted"] == 5
    assert s["decode_steps"] > 0
    assert s["tokens_generated"] >= 5 * 2    # max_new=3, first via prefill
    assert s["slots_live"] == 0 and s["queue_depth"] == 0
    # 5 prefills + many decode steps over 2 signatures -> mostly hits
    cc = s["compile_cache"]
    assert cc["misses"] >= 2 and cc["hits"] > cc["misses"]


def test_engine_run_truncates_instead_of_dropping(setup):
    """A request still in flight (or still queued) when run() hits
    max_steps comes back marked ``truncated`` — never silently dropped —
    and the engine is left clean (slots recycled, queue depth 0)."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=64))
    reqs = [Request(rid=i, prompt=(np.arange(4) % cfg.vocab), max_new=50)
            for i in range(4)]
    done = eng.run(list(reqs), max_steps=3)
    assert len(done) == 4                      # every submission accounted
    assert {r.rid for r in done} == {0, 1, 2, 3}
    assert sum(r.truncated for r in done) == 4   # nobody could finish in 3
    assert not any(r.done for r in done)
    s = eng.stats()
    assert s["truncated"] == 4
    assert s["slots_live"] == 0 and s["queue_depth"] == 0
    # a fresh run completes and stays truncation-free
    [ok] = eng.run([Request(rid=9, prompt=(np.arange(4) % cfg.vocab),
                            max_new=3)])
    assert ok.done and not ok.truncated


def test_engine_run_returns_all_in_completion_order(setup):
    """Mixed lengths: run() returns every request exactly once, finished
    ones first in completion order, none re-scanned from the workload
    list (the O(n^2) done-rescan bookkeeping bug)."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=64))
    reqs = [Request(rid=i, prompt=(np.arange(4) % cfg.vocab),
                    max_new=2 + 3 * i) for i in range(4)]
    done = eng.run(list(reqs))
    assert [r.rid for r in done] == sorted(
        (r.rid for r in reqs), key=lambda i: reqs[i].max_new)
    assert all(r.done and not r.truncated for r in done)
    assert [len(r.out) for r in done] == sorted(r.max_new for r in reqs)


def test_engine_pos_stays_int32(setup):
    """Per-slot positions are stored int32 so step() feeds decode without
    a per-call downcast copy."""
    cfg, params = setup
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=64))
    assert eng.pos.dtype == np.int32
    eng.run([Request(rid=0, prompt=(np.arange(4) % cfg.vocab), max_new=3)])
    assert eng.pos.dtype == np.int32


def test_engine_interleaved_lengths_are_isolated(setup):
    """Two concurrent requests with different prompt lengths produce the
    same tokens as when served alone (slot isolation under per-slot pos)."""
    cfg, params = setup
    pa = np.arange(5) % cfg.vocab
    pb = (np.arange(9) * 3) % cfg.vocab

    def alone(p):
        eng = ServingEngine(params, cfg,
                            ServeConfig(batch_slots=1, max_len=64))
        [r] = eng.run([Request(rid=0, prompt=p, max_new=4)])
        return r.out

    want_a, want_b = alone(pa), alone(pb)
    eng = ServingEngine(params, cfg, ServeConfig(batch_slots=2, max_len=64))
    done = eng.run([Request(rid=0, prompt=pa, max_new=4),
                    Request(rid=1, prompt=pb, max_new=4)])
    got = {r.rid: r.out for r in done}
    assert got[0] == want_a
    assert got[1] == want_b
