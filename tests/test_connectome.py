"""Connectome container + synthetic generator (paper Figs 2-3 statistics)."""

import os

import numpy as np

from conftest import given, requires_hypothesis, settings, st

from repro.core import (cache_path, from_edges, synthetic_flywire,
                        synthetic_flywire_cached)
from repro.core.connectome import _transpose_csr


def test_generator_statistics():
    c = synthetic_flywire(n=5000, target_synapses=150_000, seed=0)
    s = c.stats()
    assert s["n_neurons"] == 5000
    # paper: heavy-tailed degree distributions
    assert s["max_fan_in"] > 10 * c.fan_in.mean()
    assert s["max_fan_out"] > 10 * c.fan_out.mean()
    # paper: majority of weights modest, mode at +-1, signed (Dale's law)
    assert 0.2 < s["frac_w_pm1"] < 0.7
    assert 0.1 < s["frac_inhibitory"] < 0.5
    assert s["w_min"] < 0 < s["w_max"]
    c.validate()


def test_generator_weight_outlier_range():
    c = synthetic_flywire(n=20_000, target_synapses=600_000, seed=1)
    # outliers exist beyond the 9-bit cap (what makes SAR capping matter)
    assert c.in_weights.max() > 255 or c.in_weights.min() < -256


def test_from_edges_condenses_duplicates():
    # paper: 50M raw -> 15M condensed by summing same-(pre,post) weights
    pre = np.array([0, 0, 1, 0])
    post = np.array([1, 1, 2, 2])
    w = np.array([2, 3, 4, 5])
    c = from_edges(3, pre, post, w)
    assert c.nnz == 3
    dense = c.dense()
    assert dense[1, 0] == 5           # 2+3 condensed
    assert dense[2, 1] == 4
    assert dense[2, 0] == 5


def test_dense_matches_csr():
    c = synthetic_flywire(n=500, target_synapses=5_000, seed=2)
    dense = c.dense()
    fi = dense.astype(bool).sum(axis=1)
    np.testing.assert_array_equal(fi, c.fan_in)


def test_cache_keyed_on_generator_kwargs(tmp_path, monkeypatch):
    """Regression: the cache must not return a connectome built with a
    different synapse budget (or any other generator kwarg)."""
    monkeypatch.setenv("REPRO_CACHE", str(tmp_path))
    assert cache_path(300, 1) != cache_path(300, 1, target_synapses=3000)
    assert cache_path(300, 1, target_synapses=3000) == \
        cache_path(300, 1, target_synapses=3000)
    assert cache_path(300, 1, target_synapses=3000) != \
        cache_path(300, 1, target_synapses=9000)
    # kwarg-free calls keep the legacy filename
    assert os.path.basename(cache_path(300, 1)) == "connectome_300_1.npz"

    small = synthetic_flywire_cached(n=300, seed=1, target_synapses=3000)
    big = synthetic_flywire_cached(n=300, seed=1, target_synapses=9000)
    assert big.nnz > 2 * small.nnz          # no silent collision
    again = synthetic_flywire_cached(n=300, seed=1, target_synapses=3000)
    assert again.nnz == small.nnz
    np.testing.assert_array_equal(again.in_indices, small.in_indices)
    assert len(list(tmp_path.iterdir())) == 2


@requires_hypothesis
@settings(max_examples=25, deadline=None)
@given(st.integers(10, 60), st.integers(20, 300), st.integers(0, 10_000))
def test_transpose_roundtrip(n, nnz, seed):
    """Property: in-CSR -> out-CSR -> in-CSR is the identity."""
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, n, nnz)
    post = rng.integers(0, n, nnz)
    w = rng.integers(-50, 50, nnz)
    c = from_edges(n, pre, post, w)
    t_indptr, t_indices, t_w = _transpose_csr(
        c.n, c.in_indptr, c.in_indices, c.in_weights)
    b_indptr, b_indices, b_w = _transpose_csr(c.n, t_indptr, t_indices, t_w)
    np.testing.assert_array_equal(b_indptr, c.in_indptr)
    # within-row order may permute; compare (row, col, w) multisets
    rows_a = np.repeat(np.arange(n), np.diff(c.in_indptr))
    rows_b = np.repeat(np.arange(n), np.diff(b_indptr))
    a = sorted(zip(rows_a, c.in_indices, c.in_weights))
    b = sorted(zip(rows_b, b_indices, b_w))
    assert a == b


@requires_hypothesis
@settings(max_examples=20, deadline=None)
@given(st.integers(5, 40), st.integers(1, 200), st.integers(0, 99))
def test_from_edges_preserves_total_weight(n, nnz, seed):
    rng = np.random.default_rng(seed)
    pre = rng.integers(0, n, nnz)
    post = rng.integers(0, n, nnz)
    w = rng.integers(-9, 9, nnz)
    c = from_edges(n, pre, post, w)
    assert c.in_weights.sum() == w.sum()
    assert c.out_weights.sum() == w.sum()
