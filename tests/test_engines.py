"""Engine equivalence: dense / csr / ell / event / binned / blocked must
agree (the paper's 'same network, different delivery strategy' invariant)."""

import numpy as np
import pytest

from repro.core import (SimConfig, auto_capacity, available_engines,
                        get_engine, simulate, synthetic_flywire)
from repro.core.engine import spike_rates_hz


@pytest.fixture(scope="module")
def net():
    c = synthetic_flywire(n=1500, target_synapses=45_000, seed=3)
    sugar = np.arange(20)
    return c, sugar


ENGINES = ["dense", "csr", "ell", "event", "binned", "blocked",
           "blocked_fused"]


def test_registry_lists_all_builtin_engines():
    assert set(ENGINES) <= set(available_engines())
    for name in ENGINES:
        eng = get_engine(name)
        assert eng.name == name
    with pytest.raises(ValueError, match="unknown engine"):
        get_engine("no-such-engine")


@pytest.mark.parametrize("engine", ENGINES[1:])
def test_engines_agree_exactly(net, engine):
    """Same seed => identical RNG stream => identical spike counts."""
    c, sugar = net
    ref = simulate(c, SimConfig(engine="dense"), 400, sugar, seed=7)
    out = simulate(c, SimConfig(engine=engine), 400, sugar, seed=7)
    np.testing.assert_array_equal(np.asarray(ref.counts),
                                  np.asarray(out.counts))
    assert int(out.dropped) == 0


@pytest.mark.parametrize("qbits", [None, 9])
def test_blocked_engine_matches_csr(net, qbits):
    """Tile-gated Pallas delivery is a storage change, not an approximation:
    integer weights sum exactly in f32, so spike counts are bit-identical."""
    c, sugar = net
    a = simulate(c, SimConfig(engine="csr", quantize_bits=qbits), 300,
                 sugar, seed=7)
    b = simulate(c, SimConfig(engine="blocked", quantize_bits=qbits), 300,
                 sugar, seed=7)
    np.testing.assert_array_equal(np.asarray(a.counts), np.asarray(b.counts))
    assert int(b.dropped) == 0


def test_event_engine_budget_drops_are_counted(net):
    c, sugar = net
    cfg = SimConfig(engine="event", syn_budget=256, background_rate_hz=200.0)
    out = simulate(c, cfg, 100, sugar, seed=0)
    assert int(out.dropped) > 0     # deliberately starved budget


def test_event_auto_capacity_matches_csr_exactly(net):
    """Drop-accounting regression: auto_capacity provisioning must leave the
    event engine lossless (dropped == 0) and bit-identical to csr, while an
    under-provisioned budget on the same workload reports every loss."""
    c, _ = net
    rate = 40.0
    cap = auto_capacity(c, rate)
    base = dict(background_rate_hz=rate, poisson_rate_hz=0.0)
    ref = simulate(c, SimConfig(engine="csr", **base), 200, None, seed=2)
    out = simulate(c, SimConfig(engine="event", **cap.as_config_kwargs(),
                                **base), 200, None, seed=2)
    assert int(out.dropped) == 0
    np.testing.assert_array_equal(np.asarray(ref.counts),
                                  np.asarray(out.counts))
    starved = simulate(c, SimConfig(engine="event",
                                    spike_capacity=cap.spike_capacity,
                                    syn_budget=64, **base), 200, None, seed=2)
    assert int(starved.dropped) > 0


@pytest.mark.parametrize("rate", [0.5, 2.0, 10.0, 40.0])
def test_auto_capacity_lossless_at_every_sweep_rate(net, rate):
    """The percentile-aware joint provisioning must leave the event engine
    lossless (dropped == 0) across the whole activity sweep — the regime
    where the legacy mean-fan-out budget could silently starve on
    simultaneous hub spikes."""
    c, _ = net
    cap = auto_capacity(c, rate)
    out = simulate(c, SimConfig(engine="event", background_rate_hz=rate,
                                poisson_rate_hz=0.0,
                                **cap.as_config_kwargs()), 200, None, seed=4)
    assert int(out.dropped) == 0


def test_auto_capacity_fanout_statistics():
    c = synthetic_flywire(n=1500, target_synapses=45_000, seed=3)
    mean = auto_capacity(c, 5.0, fanout="mean")
    p99 = auto_capacity(c, 5.0, fanout="p99")
    mx = auto_capacity(c, 5.0, fanout="max")
    assert mean.spike_capacity == p99.spike_capacity == mx.spike_capacity
    assert mx.syn_budget >= p99.syn_budget   # bigger hub cushion
    assert p99.block_capacity >= 1
    with pytest.raises(ValueError, match="fanout statistic"):
        auto_capacity(c, 5.0, fanout="median")


def test_event_overflow_drops_exact_and_prefix_delivered(net):
    """Overflow contract: with starved budgets the event engine must (a)
    report *exactly* the synapses it failed to deliver — including the
    fan-out of spikes beyond spike/block capacity, which the flat
    compaction used to drop silently — and (b) deliver a subset that
    agrees with dense on every non-dropped synapse."""
    from repro.core.engine import build_synapses
    from repro.core.engines import get_engine
    from test_compaction import np_two_level

    c, _ = net
    rng = np.random.default_rng(0)
    spikes = np.zeros(c.n, bool)
    spikes[rng.choice(c.n, 40, replace=False)] = True
    fo = np.diff(c.out_indptr)
    requested = int(fo[spikes].sum())

    for cap, bcap, budget in [(8, 2, 64), (16, 4, 128), (64, 64, 10**6)]:
        cfg = SimConfig(engine="event", spike_capacity=cap, syn_budget=budget,
                        block_capacity=bcap)
        syn = build_synapses(c, cfg)
        g, dropped = get_engine("event").deliver(syn, np.asarray(spikes), cfg)

        kept = np_two_level(spikes, cap, bcap)
        kept = kept[kept < c.n]
        syn_flat = np.concatenate(
            [np.arange(c.out_indptr[i], c.out_indptr[i + 1]) for i in kept]
            or [np.array([], int)])[:budget]
        g_ref = np.zeros(c.n, np.float64)
        np.add.at(g_ref, c.out_indices[syn_flat], c.out_weights[syn_flat])
        np.testing.assert_array_equal(np.asarray(g), g_ref)
        assert int(dropped) == requested - len(syn_flat)
    # the unstarved case delivered everything
    assert requested - len(syn_flat) == 0


def test_fixed_point_engine_close_to_float(net):
    """Paper Fig 12: fixed-point hardware path tracks the float reference
    statistically (spike-rate parity)."""
    from repro.core import parity
    c, sugar = net
    T = 500
    f = simulate(c, SimConfig(engine="csr", poisson_to_v=False), T, sugar,
                 seed=11)
    x = simulate(c, SimConfig(engine="csr", poisson_to_v=False,
                              fixed_point=True), T, sugar, seed=11)
    rf = np.asarray(spike_rates_hz(f.counts, T, 0.1))
    rx = np.asarray(spike_rates_hz(x.counts, T, 0.1))
    st = parity(rf, rx)
    assert st.n_active > 0
    # identical Poisson stream; only integration arithmetic differs
    assert st.frac_within_1hz > 0.9 or st.rmse_hz < 2.0, st.summary()


def test_quantization_ablation_changes_outliers_only(net):
    """Paper Fig 13 (capped weights): quantizing to 9 bits perturbs rates
    but keeps the network in a similar regime."""
    c, sugar = net
    T = 400
    a = simulate(c, SimConfig(engine="csr"), T, sugar, seed=5)
    b = simulate(c, SimConfig(engine="csr", quantize_bits=9), T, sugar,
                 seed=5)
    ca, cb = int(a.counts.sum()), int(b.counts.sum())
    assert cb > 0
    assert abs(ca - cb) / max(ca, 1) < 0.5


def test_raster_collection(net):
    c, sugar = net
    out = simulate(c, SimConfig(engine="csr", collect_raster=True), 50,
                   sugar, seed=0)
    assert out.raster.shape == (50, c.n)
    np.testing.assert_array_equal(
        np.asarray(out.raster).sum(0), np.asarray(out.counts))


def test_background_scaling_activity_increases(net):
    """Scaling study substrate: higher background rate => more spikes."""
    c, _ = net
    counts = []
    for rate in (0.0, 5.0, 40.0):
        cfg = SimConfig(engine="csr", background_rate_hz=rate,
                        poisson_rate_hz=0.0)
        out = simulate(c, cfg, 200, None, seed=1)
        counts.append(int(out.counts.sum()))
    assert counts[0] == 0
    assert counts[1] < counts[2]
