"""Greedy capacity partitioner + SNN-dCSR IR (paper §3.2.4, Figs 8-10)."""

import numpy as np
import pytest

from repro.core import (CoreBudget, caps_from_budget, even_partition,
                        greedy_partition, partition_report, synthetic_flywire)
from repro.core.dcsr import build_dcsr, edge_cut
from repro.core.partition import PartitionCaps


@pytest.fixture(scope="module")
def net():
    return synthetic_flywire(n=3000, target_synapses=90_000, seed=5)


def test_greedy_respects_caps(net):
    caps = PartitionCaps(max_neurons=200, max_in_units=20_000,
                         max_out_units=20_000)
    p = greedy_partition(net, caps, scheme="sar")
    rep = partition_report(net, p, CoreBudget.loihi2())
    assert (rep["neurons"] <= caps.max_neurons).all()
    assert (rep["eff_fan_in"] <= caps.max_in_units).all()
    assert (rep["fan_out"] <= caps.max_out_units).all()


def test_greedy_beats_even_on_memory_balance(net):
    """The paper's point: even neuron-count splitting overcommits cores
    holding outlier neurons."""
    caps = caps_from_budget(CoreBudget.loihi2(), "sar")
    g = greedy_partition(net, caps, scheme="sar")
    e = even_partition(net, g.n_parts)
    rep_g = partition_report(net, g, CoreBudget.loihi2())
    rep_e = partition_report(net, e, CoreBudget.loihi2())
    # greedy never exceeds the synaptic-memory budget; even split may
    assert rep_g["mem_util"].max() <= 1.0 + 1e-9
    assert rep_e["mem_util"].max() >= rep_g["mem_util"].max() - 1e-9


def test_partition_covers_all_neurons(net):
    caps = PartitionCaps(max_neurons=500, max_in_units=50_000,
                         max_out_units=50_000)
    p = greedy_partition(net, caps, scheme="ssd")
    assert p.offsets[0] == 0 and p.offsets[-1] == net.n
    assert (np.diff(p.offsets) > 0).all()
    np.testing.assert_array_equal(
        np.bincount(p.part_of_neuron, minlength=p.n_parts),
        np.diff(p.offsets))


def test_dcsr_preserves_all_synapses(net):
    caps = PartitionCaps(max_neurons=800, max_in_units=80_000,
                         max_out_units=80_000)
    p = greedy_partition(net, caps, scheme="sar")
    d = build_dcsr(net, p)
    valid = d.syn_src < d.n_parts * d.part_size
    assert int(valid.sum()) == net.nnz
    # every synapse maps back to an original (src, tgt, w) triple
    P_, U = d.n_parts, d.part_size
    qs, ks = np.nonzero(valid)
    src_orig = d.inv_perm[d.syn_src[qs, ks]]
    tgt_orig = d.inv_perm[qs * U + d.syn_tgt_local[qs, ks]]
    w = d.syn_w[qs, ks]
    got = sorted(zip(tgt_orig, src_orig, w.astype(np.int64)))
    rows = np.repeat(np.arange(net.n), net.fan_in)
    want = sorted(zip(rows, net.in_indices, net.in_weights.astype(np.int64)))
    assert got == want


def test_edge_cut_stats(net):
    caps = PartitionCaps(max_neurons=400, max_in_units=40_000,
                         max_out_units=40_000)
    p = greedy_partition(net, caps, scheme="sar")
    d = build_dcsr(net, p)
    ec = edge_cut(d)
    assert ec["n_synapses"] == net.nnz
    assert 0.0 < ec["frac_remote"] < 1.0


def test_loihi_budget_reproduces_paper_scale_shape():
    """At full FlyWire scale the paper lands on 12 chips (1440 cores) with
    SAR vs 20 chips with SSD; on the reduced synthetic graph we check the
    *ordering* (SAR needs fewer partitions than SSD at equal budget)."""
    c = synthetic_flywire(n=8000, target_synapses=400_000, seed=6)
    budget = CoreBudget.loihi2()
    p_sar = greedy_partition(c, caps_from_budget(budget, "sar"), "sar")
    p_ssd = greedy_partition(c, caps_from_budget(budget, "ssd"), "ssd")
    assert p_sar.n_parts <= p_ssd.n_parts
