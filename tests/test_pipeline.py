"""Pipeline parallelism schedule: emulated pipeline == sequential stack."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.parallel.pipeline import pipeline_apply_emulated


def test_pipeline_matches_sequential():
    S, M, d = 4, 6, 16
    rng = np.random.default_rng(0)
    Ws = jnp.asarray(rng.normal(0, 0.3, (S, d, d)), jnp.float32)
    xs = jnp.asarray(rng.normal(0, 1, (M, 8, d)), jnp.float32)

    def stage_fn(W, x):
        return jnp.tanh(x @ W)

    out_pipe = pipeline_apply_emulated(stage_fn, Ws, xs, n_stages=S)

    out_seq = xs
    for s in range(S):
        out_seq = jax.vmap(lambda x: stage_fn(Ws[s], x))(out_seq)
    np.testing.assert_allclose(np.asarray(out_pipe), np.asarray(out_seq),
                               atol=1e-5)


def test_pipeline_bubble_accounting():
    """M + S - 1 ticks: outputs for every microbatch, in order."""
    S, M, d = 3, 5, 4
    Ws = jnp.stack([jnp.eye(d) * (i + 1) for i in range(S)])
    xs = jnp.arange(M * d, dtype=jnp.float32).reshape(M, d)

    def stage_fn(W, x):
        return x @ W

    out = pipeline_apply_emulated(stage_fn, Ws, xs, n_stages=S)
    want = xs * float(np.prod(range(1, S + 1)))
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5)
