"""Simulation serving layer (PR 8 acceptance): admission, batching by
compile signature, and the failure taxonomy.

Pins: (a) a request packed into a vmapped batch gets a SimResult
bit-identical to a solo ``simulate()`` run, on float32 AND Q19.12;
(b) ``run_trials(chunk_steps=K)`` is bit-neutral (the substrate the
server's chunk loop shares); (c) queue overflow sheds with
``queue_full`` and the soft watermark degrades probes instead;
(d) a deadline expires mid-run at a chunk boundary; (e) a poison request
is isolated after its first health failure and quarantined with its
:class:`SimulationHealthError` after the second, while its batch-mates
complete; (f) a crash-looping request retries with backoff, is isolated
from healthy traffic, and is finally rejected with the error attached;
(g) a drop-rate breach escalates capacity for that batch tier only;
(h) every emitted ``serve_*`` event validates against ``schema.json``
and every submitted request reaches a terminal state.
"""

import dataclasses

import numpy as np
import pytest

from repro import obs
from repro.core import (CapacityConfig, HealthConfig, SimConfig, simulate,
                        synthetic_flywire)
from repro.core.exchange import ExchangeFault
from repro.core.health import BackoffPolicy, SimulationHealthError
from repro.exp import ProbeSpec, build_scenario, run_trials
from repro.serving import (COMPLETED, QUARANTINED, REJECTED, SimRequest,
                           SimServeConfig, SimServer)

N, SYN, T = 300, 6_000, 60
PROBES = ProbeSpec(raster=True, pop_rate=True)
FAST = BackoffPolicy(base_s=0.0, jitter=0.0)     # no real sleeping in tests


@pytest.fixture(scope="module")
def c():
    return synthetic_flywire(n=N, target_synapses=SYN, seed=0)


def _server(c, *, cfg=None, clock=None, **serve_kw):
    cfg = cfg if cfg is not None else SimConfig(engine="csr")
    serve_kw.setdefault("backoff", FAST)
    serve_kw.setdefault("chunk_steps", 20)
    kw = {"clock": clock} if clock is not None else {}
    return SimServer(c, cfg, SimServeConfig(**serve_kw),
                     sleep=lambda s: None, **kw)


def _req(seed, scenario="sugar_feeding", t=T, **kw):
    kw.setdefault("probes", PROBES)
    return SimRequest(scenario=scenario, t_steps=t, seed=seed, **kw)


def _assert_bitwise(a, b):
    assert np.array_equal(np.asarray(a.counts), np.asarray(b.counts))
    for k in a.records:
        assert np.array_equal(np.asarray(a.records[k]),
                              np.asarray(b.records[k])), k
    assert np.array_equal(np.asarray(a.state.v), np.asarray(b.state.v))
    assert int(np.asarray(a.dropped).sum()) == int(np.asarray(b.dropped).sum())


# --------------------------------------------------------------------------
# (a) packed == solo, bitwise
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fx", [False, True], ids=["f32", "q19.12"])
def test_batched_request_bit_identical_to_solo(c, fx):
    """The core serving claim: batching by signature onto one vmapped
    scan never changes a request's numbers — on both arithmetics."""
    cfg = SimConfig(engine="csr", fixed_point=fx)
    srv = _server(c, cfg=cfg, max_batch=4)
    reqs = [_req(seed=s) for s in (3, 7, 11)]
    done = srv.run(reqs)
    assert [r.status for r in done] == [COMPLETED] * 3
    assert srv.stats()["batches"] == 1      # one signature -> one vmap scan
    stim = build_scenario("sugar_feeding", c, srv.cfg)
    for r in reqs:
        solo = simulate(c, srv.cfg, T, stimulus=stim, seed=r.seed,
                        probes=PROBES)
        _assert_bitwise(solo, r.result)


def test_mixed_signatures_split_batches(c):
    """Different params/probes -> different compile signatures -> never
    packed together; a solo-flagged request is never batched at all."""
    srv = _server(c, max_batch=8)
    a = _req(seed=0)
    b = _req(seed=1, scenario="step_response", probes=ProbeSpec(pop_rate=True))
    lone = _req(seed=2)
    lone.solo = True
    srv.run([a, b, lone])
    assert srv.stats()["batches"] == 3


# --------------------------------------------------------------------------
# (b) the chunked trial substrate is bit-neutral
# --------------------------------------------------------------------------

def test_run_trials_chunked_bit_identity(c):
    cfg = SimConfig(engine="csr", health=HealthConfig())
    stim = build_scenario("sugar_feeding", c, cfg)
    ref = run_trials(c, cfg, 50, stimulus=stim, seeds=3, probes=PROBES)
    chk = run_trials(c, cfg, 50, stimulus=stim, seeds=3, probes=PROBES,
                     chunk_steps=16)                    # 16+16+16+2
    assert np.array_equal(np.asarray(ref.counts), np.asarray(chk.counts))
    for k in ref.records:
        assert np.array_equal(np.asarray(ref.records[k]),
                              np.asarray(chk.records[k])), k
    assert np.array_equal(np.asarray(ref.state.v), np.asarray(chk.state.v))


# --------------------------------------------------------------------------
# (c) admission control: shed + degrade
# --------------------------------------------------------------------------

def test_queue_overflow_sheds_with_reason(c):
    srv = _server(c, max_queue=2)
    reqs = [_req(seed=s) for s in range(4)]
    for r in reqs:
        srv.submit(r)
    assert [r.status for r in reqs] == ["queued", "queued",
                                       REJECTED, REJECTED]
    assert all(r.reason == "queue_full" for r in reqs[2:])
    s = srv.stats()
    assert s["shed"] == 2 and s["rejected"] == 2
    # shed requests are already terminal; the queue drains the rest
    done = srv.run()
    assert {r.status for r in done if r in reqs[:2]} == {COMPLETED}


def test_degradation_under_queue_pressure(c):
    """Past the soft watermark, admissions trade per-neuron probes for
    scalar ones (and shorter chunks) instead of being shed."""
    srv = _server(c, max_queue=8, degrade_queue_depth=2,
                  degraded_chunk_steps=10)
    reqs = [_req(seed=s) for s in range(4)]
    for r in reqs:
        srv.submit(r)
    assert [r.degraded for r in reqs] == [False, False, True, True]
    assert reqs[2].probes == ProbeSpec(pop_rate=True)   # raster stripped
    done = srv.run()
    assert all(r.status == COMPLETED for r in done)
    assert "raster" not in reqs[3].result.records
    assert "pop_rate_hz" in reqs[3].result.records
    assert srv.stats()["degraded"] == 2


# --------------------------------------------------------------------------
# (d) deadlines at chunk boundaries
# --------------------------------------------------------------------------

def test_deadline_expires_mid_chunk(c):
    """A fake clock advancing per call: the request's budget runs out
    while its batch is mid-flight, and the lane is cut at the next chunk
    boundary while the batch-mate completes."""
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    srv = _server(c, clock=clock, chunk_steps=20)
    tight = _req(seed=0, deadline_s=2.0)     # expires during the run
    loose = _req(seed=1)                     # no deadline
    done = srv.run([tight, loose])
    assert tight.status == REJECTED and tight.reason == "deadline"
    assert tight.result is None
    assert loose.status == COMPLETED
    assert srv.stats()["deadline_expired"] == 1
    assert len(done) == 2


def test_deadline_sheds_before_dispatch(c):
    """An already-expired queue entry is shed at tick time without
    burning a batch slot."""
    t = [0.0]

    def clock():
        t[0] += 100.0
        return t[0]

    srv = _server(c, clock=clock)
    r = _req(seed=0, deadline_s=1.0)
    srv.run([r])
    assert r.status == REJECTED and r.reason == "deadline"
    assert srv.stats()["batches"] == 0


# --------------------------------------------------------------------------
# (e) poison quarantine with per-lane attribution
# --------------------------------------------------------------------------

def test_poison_quarantined_after_two_failures_batchmates_survive(c):
    """A NaN-stimulus request fails its lane's health check, is retried
    solo (isolation), fails again, and is quarantined with the health
    error attached — while a healthy request of the same scenario (its
    own signature tier) completes with finite records."""
    cfg = SimConfig(engine="csr", health=HealthConfig())
    srv = _server(c, cfg=cfg, max_batch=4)
    poison = _req(seed=0, scenario="step_response",
                  params={"amp": float("nan")},
                  probes=ProbeSpec(pop_rate=True))
    healthy = _req(seed=1, scenario="step_response", params={"amp": 1.0},
                   probes=ProbeSpec(pop_rate=True))
    done = srv.run([poison, healthy])
    assert poison.status == QUARANTINED
    assert poison.reason == "nonfinite"
    assert isinstance(poison.error, SimulationHealthError)
    assert poison.error.kind == "nonfinite"
    assert poison.health_failures == 2
    assert poison.solo                      # never re-batched with healthy
    assert healthy.status == COMPLETED
    assert np.isfinite(
        np.asarray(healthy.result.records["pop_rate_hz"])).all()
    s = srv.stats()
    assert s["quarantined"] == 1 and s["completed"] == 1
    assert len(done) == 2


# --------------------------------------------------------------------------
# (f) crash retry with backoff, isolation, exhaustion
# --------------------------------------------------------------------------

def test_crash_retried_then_completes(c):
    fired = []

    def hook(start, stop):
        if not fired:
            fired.append(start)
            raise ExchangeFault("injected host fault")

    srv = _server(c)
    r = _req(seed=0)
    r.fault_hook = hook
    srv.run([r])
    assert r.status == COMPLETED and r.attempts == 1
    assert srv.stats()["retries"] == 1
    # the retried result is still the solo-run truth
    stim = build_scenario("sugar_feeding", c, srv.cfg)
    _assert_bitwise(simulate(c, srv.cfg, T, stimulus=stim, seed=0,
                             probes=PROBES), r.result)


def test_crash_loop_isolates_then_rejects(c):
    """Persistent crasher: its hook-attributed crash isolates it (solo)
    from the first failure on, so the healthy batch-mate requeues free —
    no attempt charged — and completes; after ``max_retries`` the
    crasher is rejected with the error attached."""
    def hook(start, stop):
        raise ExchangeFault("always broken")

    srv = _server(c, max_retries=2)
    crashy = _req(seed=0)
    crashy.fault_hook = hook
    buddy = _req(seed=1)
    done = srv.run([crashy, buddy])
    assert crashy.status == REJECTED and crashy.reason == "crash"
    assert isinstance(crashy.error, ExchangeFault)
    assert crashy.attempts == 3             # initial + 2 retries
    assert crashy.solo
    assert buddy.status == COMPLETED
    assert buddy.attempts == 0              # attributed crash: no blame
    assert not buddy.solo
    assert len(done) == 2


def test_backoff_delays_scheduled_on_retry(c):
    """Retry gates honour BackoffPolicy: requeued requests carry a
    ``not_before`` in the future and the drain loop waits them out."""
    waits = []
    t = [0.0]

    def sleep(s):
        waits.append(s)
        t[0] += s

    srv = SimServer(c, SimConfig(engine="csr"),
                    SimServeConfig(chunk_steps=20,
                                   backoff=BackoffPolicy(base_s=0.5,
                                                         factor=2.0,
                                                         jitter=0.0)),
                    clock=lambda: t[0], sleep=sleep)
    fired = []

    def hook(start, stop):
        if len(fired) < 2:
            fired.append(start)
            raise ExchangeFault("flaky")

    r = _req(seed=0)
    r.fault_hook = hook
    srv.run([r])
    assert r.status == COMPLETED and r.attempts == 2
    # two waits, exponentially spaced: ~0.5s then ~1.0s
    assert len(waits) == 2
    assert waits[0] == pytest.approx(0.5, abs=0.2)
    assert waits[1] == pytest.approx(1.0, abs=0.2)


# --------------------------------------------------------------------------
# (g) batch-tier capacity escalation
# --------------------------------------------------------------------------

def test_drop_rate_escalates_batch_tier_only(c):
    """A drop-rate breach escalates capacity for THAT signature tier and
    re-runs the batch; other tiers keep the base capacity."""
    cfg = SimConfig(engine="event",
                    capacity=CapacityConfig(spike_capacity=4,
                                            syn_budget=16),
                    health=HealthConfig(max_drop_rate=0.0))
    srv = _server(c, cfg=cfg, max_batch=4, max_escalations=10)
    hungry = [_req(seed=s) for s in (0, 1)]
    done = srv.run(hungry)
    assert all(r.status == COMPLETED for r in done)
    s = srv.stats()
    assert s["escalations"] >= 1
    assert s["escalated_tiers"] == 1        # only the breached signature
    sig = srv._signature(hungry[0])
    assert srv._capacity[sig].syn_budget > 16
    # converged lossless, and still the solo truth under ample capacity
    ample = dataclasses.replace(srv.cfg, capacity=srv._capacity[sig])
    stim = build_scenario("sugar_feeding", c, srv.cfg)
    ref = simulate(c, ample, T, stimulus=stim, seed=0, probes=PROBES)
    _assert_bitwise(ref, hungry[0].result)


def test_capacity_exhaustion_rejects_batch(c):
    cfg = SimConfig(engine="event",
                    capacity=CapacityConfig(spike_capacity=1, syn_budget=2),
                    health=HealthConfig(max_drop_rate=0.0))
    srv = _server(c, cfg=cfg, max_escalations=1)
    r = _req(seed=0)
    srv.run([r])
    assert r.status == REJECTED and r.reason == "capacity"
    assert isinstance(r.error, SimulationHealthError)
    assert r.error.kind == "drop_rate"


# --------------------------------------------------------------------------
# (h) events validate; every request terminal
# --------------------------------------------------------------------------

def test_events_schema_valid_and_all_terminal(c):
    """The full mixed workload streams schema-valid serve_* events
    (validate=True raises on drift) and every submitted request —
    completed, shed, poisoned, crashed — ends terminal."""
    events = []
    fired = []

    def hook(start, stop):
        if not fired:
            fired.append(start)
            raise ExchangeFault("injected")

    cfg = SimConfig(engine="csr", health=HealthConfig())
    with obs.telemetry(events.append, validate=True):
        srv = _server(c, cfg=cfg, max_queue=3, max_batch=2)
        crashy = _req(seed=0)
        crashy.fault_hook = hook
        reqs = [crashy, _req(seed=1),
                _req(seed=2, scenario="step_response",
                     params={"amp": float("nan")},
                     probes=ProbeSpec(pop_rate=True)),
                _req(seed=3), _req(seed=4)]
        done = srv.run(reqs)
    assert len(done) == 5
    statuses = {r.rid: r.status for r in done}
    assert all(r.terminal for r in done)
    assert statuses[reqs[2].rid] == QUARANTINED
    assert sorted({e["type"] for e in events} & {
        "serve_admit", "serve_batch", "serve_retry", "serve_quarantine",
        "serve_shed", "serve_request_end"}) == [
        "serve_admit", "serve_batch", "serve_quarantine",
        "serve_request_end", "serve_retry", "serve_shed"]
    ends = [e for e in events if e["type"] == "serve_request_end"]
    assert len(ends) == 5                   # one terminal event per request
    s = srv.stats()
    assert (s["completed"] + s["rejected"] + s["quarantined"]
            == s["submitted"] == 5)


def test_stats_latency_percentiles(c):
    srv = _server(c)
    srv.run([_req(seed=s) for s in range(3)])
    s = srv.stats()
    assert s["latency_p50_s"] is not None
    assert s["latency_p99_s"] >= s["latency_p50_s"] >= 0.0
    assert s["queue_depth"] == 0
