"""Experiment subsystem: stimuli are bit-compatible with the deleted inline
drive code, probes match hand-stepped references, vmapped trial batches
match sequential runs, and the scenario registry behaves."""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SimConfig, simulate, synthetic_flywire
from repro.core.engine import build_synapses
from repro.core.engines import get_engine
from repro.core.neuron import init_state, lif_step, lif_step_fx
from repro.exp import (SILENT, Background, Compose, PoissonDrive, ProbeSpec,
                       RampDrive, SkipKey, StepCurrent, available_scenarios,
                       build_scenario, get_scenario, legacy_stimulus,
                       per_neuron, run_trials, shard_stimulus)


@pytest.fixture(scope="module")
def net():
    c = synthetic_flywire(n=1200, target_synapses=36_000, seed=4)
    sugar = np.arange(20)
    return c, sugar


# --------------------------------------------------------------------------
# Legacy bit-compatibility: PoissonDrive vs the pre-refactor sugar branch
# --------------------------------------------------------------------------

def _legacy_counts(c, cfg, t_steps, sugar_idx, seed):
    """The deleted inline stimulus code of the pre-exp `_run_scan`,
    reproduced verbatim as the bit-compatibility oracle."""
    n = c.n
    syn = build_synapses(c, cfg)
    deliver = get_engine(cfg.engine).deliver
    p = cfg.params
    p_sugar = cfg.poisson_rate_hz * p.dt * 1e-3
    p_bg = cfg.background_rate_hz * p.dt * 1e-3
    v_amp = p.v_th * 1.5
    v_amp_fx = round(v_amp / p.w_scale)
    sugar = None if sugar_idx is None else jnp.asarray(
        np.asarray(sugar_idx).astype(np.int32))

    def step(carry, _):
        lif, ring, ptr, key, counts = carry
        key, k_poisson, k_bg = jax.random.split(key, 3)
        delayed = ring[ptr]
        g_units, _ = deliver(syn, delayed, cfg)
        v_in = v_in_fx = force = None
        if sugar is not None:
            draws = jax.random.bernoulli(k_poisson, p_sugar, sugar.shape)
            if cfg.poisson_to_v:
                if cfg.fixed_point:
                    v_in_fx = jnp.zeros(n, jnp.int32).at[sugar].set(
                        draws.astype(jnp.int32) * v_amp_fx)
                else:
                    v_in = jnp.zeros(n, jnp.float32).at[sugar].set(
                        draws.astype(jnp.float32) * v_amp)
            else:
                g_units = g_units.at[sugar].add(
                    draws.astype(jnp.float32) * cfg.poisson_weight)
        if cfg.background_rate_hz > 0:
            force = jax.random.bernoulli(k_bg, p_bg, (n,))
        if cfg.fixed_point:
            g_in = jnp.round(g_units).astype(jnp.int32)
            lif, spikes = lif_step_fx(lif, g_in, p, v_in_fx, force)
        else:
            lif, spikes = lif_step(lif, g_units * p.w_scale, p, v_in, force)
        ring = ring.at[ptr].set(spikes)
        return (lif, ring, (ptr + 1) % p.delay_steps, key,
                counts + spikes.astype(jnp.int32)), None

    carry = (init_state(n, p, cfg.fixed_point),
             jnp.zeros((p.delay_steps, n), dtype=bool), jnp.int32(0),
             jax.random.PRNGKey(seed), jnp.zeros(n, jnp.int32))
    carry, _ = jax.lax.scan(step, carry, None, length=t_steps)
    return np.asarray(carry[-1])


LEGACY_CASES = [
    dict(engine="csr"),                                     # float, Brian2 v
    dict(engine="csr", poisson_to_v=False),                 # float, Loihi g
    dict(engine="csr", fixed_point=True, poisson_to_v=False,
         quantize_bits=9),                                  # CONFIG path
    dict(engine="csr", fixed_point=True, poisson_to_v=True),
    dict(engine="csr", background_rate_hz=20.0),            # sugar + bg
]


@pytest.mark.parametrize("kw", LEGACY_CASES,
                         ids=lambda kw: "-".join(f"{k}={v}"
                                                 for k, v in kw.items()))
def test_poisson_drive_bit_identical_to_legacy_sugar_branch(net, kw):
    """Acceptance: same seed => same counts as the pre-refactor inline
    sugar/background code, float and fixed-point."""
    c, sugar = net
    cfg = SimConfig(**kw)
    res = simulate(c, cfg, 300, sugar, seed=7)
    ref = _legacy_counts(c, cfg, 300, sugar, seed=7)
    np.testing.assert_array_equal(np.asarray(res.counts), ref)
    assert ref.sum() > 0


def test_background_only_keeps_legacy_key_slot(net):
    """Without sugar the old step still split 3 keys and background drew
    from the third; SkipKey preserves that layout."""
    c, _ = net
    cfg = SimConfig(engine="csr", background_rate_hz=25.0,
                    poisson_rate_hz=0.0)
    res = simulate(c, cfg, 200, None, seed=5)
    ref = _legacy_counts(c, cfg, 200, None, seed=5)
    np.testing.assert_array_equal(np.asarray(res.counts), ref)
    stim = legacy_stimulus(cfg, c.n)
    assert isinstance(stim.parts[0], SkipKey)


# --------------------------------------------------------------------------
# Probes
# --------------------------------------------------------------------------

def test_raster_probe_matches_legacy_collect_raster(net):
    """ProbeSpec(raster=True) is bit-for-bit the legacy collect_raster."""
    c, sugar = net
    legacy = simulate(c, SimConfig(engine="csr", collect_raster=True), 120,
                      sugar, seed=0)
    probed = simulate(c, SimConfig(engine="csr"), 120, sugar, seed=0,
                      probes=ProbeSpec(raster=True))
    assert legacy.raster is not None and probed.raster is not None
    np.testing.assert_array_equal(np.asarray(legacy.raster),
                                  np.asarray(probed.raster))
    np.testing.assert_array_equal(
        np.asarray(probed.records["raster"]).sum(0),
        np.asarray(probed.counts))


def test_voltage_probe_matches_hand_stepped_lif(net):
    """Voltage trace under a deterministic StepCurrent equals a hand-run
    loop of lif_step with an explicit delay ring buffer."""
    c, _ = net
    cfg = SimConfig(engine="csr")
    p = cfg.params
    ids = (3, 100, 777)
    stim = Compose((StepCurrent(weights=per_neuron(list(ids), 90.0, c.n),
                                t_on=10, t_off=60),))
    T = 100
    res = simulate(c, cfg, T, seed=0, stimulus=stim,
                   probes=ProbeSpec(voltage=ids, raster=True))
    # hand loop
    syn = build_synapses(c, cfg)
    deliver = get_engine(cfg.engine).deliver
    w = np.zeros(c.n, np.float32)
    w[list(ids)] = 90.0
    lif = init_state(c.n, p)
    ring = jnp.zeros((p.delay_steps, c.n), dtype=bool)
    trace = []
    for t in range(T):
        g_units, _ = deliver(syn, ring[t % p.delay_steps], cfg)
        g_units = g_units + jnp.asarray(w) * (1.0 if 10 <= t < 60 else 0.0)
        lif, spikes = lif_step(lif, g_units * p.w_scale, p, None, None)
        ring = ring.at[t % p.delay_steps].set(spikes)
        trace.append(np.asarray(lif.v)[list(ids)])
    np.testing.assert_array_equal(np.asarray(res.records["v"]),
                                  np.stack(trace))
    assert np.asarray(res.counts).sum() > 0   # the step drive elicits spikes


def test_pop_rate_and_drop_probes(net):
    c, sugar = net
    cfg = SimConfig(engine="csr", background_rate_hz=50.0)
    T = 80
    res = simulate(c, cfg, T, sugar, seed=1,
                   probes=ProbeSpec(raster=True, pop_rate=True, drops=True))
    raster = np.asarray(res.records["raster"])
    expect = raster.mean(axis=1) / (cfg.params.dt * 1e-3)
    np.testing.assert_allclose(np.asarray(res.records["pop_rate_hz"]),
                               expect, rtol=1e-5)
    assert res.records["dropped"].shape == (T,)
    assert int(np.asarray(res.records["dropped"]).sum()) == int(res.dropped)


# --------------------------------------------------------------------------
# Vmapped trial batches
# --------------------------------------------------------------------------

def test_run_trials_matches_sequential_simulate(net):
    """Acceptance: run_trials(batch) == the same seeds run one by one."""
    c, sugar = net
    cfg = SimConfig(engine="csr", background_rate_hz=10.0)
    seeds = [3, 11, 42, 7]
    batch = run_trials(c, cfg, 150, sugar, seeds=seeds)
    assert batch.counts.shape == (4, c.n)
    for i, s in enumerate(seeds):
        one = simulate(c, cfg, 150, sugar, seed=s)
        np.testing.assert_array_equal(np.asarray(batch.counts[i]),
                                      np.asarray(one.counts))
        assert int(batch.dropped[i]) == int(one.dropped)
    rates = batch.mean_rates_hz(150, cfg.params.dt)
    assert rates.shape == (c.n,)
    np.testing.assert_allclose(
        rates, np.asarray(batch.counts).mean(0) / (150 * 0.1e-3))


def test_run_trials_batched_probes(net):
    c, sugar = net
    batch = run_trials(c, SimConfig(engine="csr"), 60, sugar, seeds=3,
                       probes=ProbeSpec(raster=True))
    assert batch.records["raster"].shape == (3, 60, c.n)
    np.testing.assert_array_equal(
        np.asarray(batch.records["raster"]).sum(axis=1),
        np.asarray(batch.counts))


# --------------------------------------------------------------------------
# Stimuli semantics + scenario registry
# --------------------------------------------------------------------------

def test_silent_baseline_is_silent(net):
    c, _ = net
    res = simulate(c, SimConfig(engine="csr"), 200, stimulus=SILENT)
    assert int(np.asarray(res.counts).sum()) == 0


def test_step_response_window(net):
    """Spikes only appear after the step turns on."""
    c, _ = net
    cfg = SimConfig(engine="csr")
    stim = build_scenario("step_response", c, cfg, t_on=50, t_off=150)
    res = simulate(c, cfg, 200, seed=0, stimulus=stim,
                   probes=ProbeSpec(raster=True))
    raster = np.asarray(res.records["raster"])
    assert raster[:50].sum() == 0
    assert raster[50:].sum() > 0


def test_pulse_and_ramp_scenarios_drive_activity(net):
    c, _ = net
    cfg = SimConfig(engine="csr")
    for name in ("pulse_probe", "opto_ramp"):
        stim = build_scenario(name, c, cfg)
        res = simulate(c, cfg, 500, seed=0, stimulus=stim)
        assert int(np.asarray(res.counts).sum()) > 0, name


def test_ramp_is_ramped(net):
    """Early-window ramp drive is strictly below the late-window plateau."""
    c, _ = net
    cfg = SimConfig(engine="csr")
    stim = Compose((RampDrive(weights=per_neuron(np.arange(50), 60.0, c.n),
                              t_on=0, t_ramp=400, t_off=None),))
    res = simulate(c, cfg, 400, seed=0, stimulus=stim,
                   probes=ProbeSpec(raster=True))
    raster = np.asarray(res.records["raster"])
    assert raster[:100].sum() < raster[300:].sum()


def test_scenario_registry(net):
    c, _ = net
    names = available_scenarios()
    for required in ("sugar_feeding", "activity_sweep", "background_storm",
                     "silent_baseline"):
        assert required in names
    with pytest.raises(ValueError, match="unknown scenario"):
        get_scenario("no-such-scenario")
    with pytest.raises(ValueError, match="no params"):
        build_scenario("silent_baseline", c, SimConfig(), bogus=1)
    # background level is a scenario parameter: more background, more spikes
    cfg = SimConfig(engine="csr")
    lo = simulate(c, cfg, 150, seed=2, stimulus=build_scenario(
        "activity_sweep", c, cfg, background_hz=2.0))
    hi = simulate(c, cfg, 150, seed=2, stimulus=build_scenario(
        "activity_sweep", c, cfg, background_hz=40.0))
    assert int(lo.counts.sum()) < int(hi.counts.sum())


def test_compose_adds_drives(net):
    """Composing two Poisson-g drives equals one drive at the summed
    weight when their draws coincide (same population, same key slot
    consumed per part => different draws; so test additivity via
    deterministic StepCurrent instead)."""
    c, _ = net
    cfg = SimConfig(engine="csr")
    w = per_neuron(np.arange(30), 40.0, c.n)
    two = Compose((StepCurrent(weights=w), StepCurrent(weights=w)))
    one = Compose((StepCurrent(weights=per_neuron(np.arange(30), 80.0, c.n)),))
    ra = simulate(c, cfg, 100, seed=0, stimulus=two)
    rb = simulate(c, cfg, 100, seed=0, stimulus=one)
    np.testing.assert_array_equal(np.asarray(ra.counts),
                                  np.asarray(rb.counts))


# --------------------------------------------------------------------------
# Distributed path accepts the same stimulus pytrees
# --------------------------------------------------------------------------

def test_distributed_accepts_stimulus_pytrees(net):
    """Passing the legacy-equivalent stimulus explicitly reproduces the
    default (sugar_neurons) distributed path bit-for-bit, and a scenario
    stimulus runs through shard_map emulation unchanged."""
    from repro.core.dcsr import build_dcsr
    from repro.core.distributed import DistConfig, simulate_distributed
    from repro.core.partition import even_partition
    c, sugar = net
    d = build_dcsr(c, even_partition(c, 4))
    sim = SimConfig(engine="csr")
    dcfg = DistConfig(sim=sim, scheme="event")
    a = simulate_distributed(d, dcfg, 150, sugar, seed=3, emulate=True)
    stim = legacy_stimulus(sim, c.n, sugar_idx=sugar, masked=True)
    b = simulate_distributed(d, dcfg, 150, seed=3, emulate=True,
                             stimulus=stim)
    np.testing.assert_array_equal(a.counts, b.counts)
    # a registry scenario (scatter-mode) is shardable via to_masked
    storm = build_scenario("background_storm", c, sim, background_hz=30.0)
    r = simulate_distributed(d, dcfg, 100, seed=1, emulate=True,
                             stimulus=storm)
    assert r.counts.sum() > 0


def test_shard_stimulus_remaps_per_neuron_leaves(net):
    from repro.core.dcsr import build_dcsr
    from repro.core.partition import even_partition
    c, sugar = net
    d = build_dcsr(c, even_partition(c, 4))
    stim = Compose((PoissonDrive(idx=jnp.asarray(sugar.astype(np.int32))),
                    Background(rate_hz=5.0)))
    sh = shard_stimulus(stim, d)
    pois, bg = sh.parts
    assert pois.idx is None
    assert pois.mask.shape == (d.n_parts, d.part_size)
    # mask marks exactly the sugar neurons, at their renumbered positions
    flat = np.asarray(pois.mask).reshape(-1)
    assert flat.sum() == len(sugar)
    assert set(np.flatnonzero(flat)) == set(np.asarray(d.perm)[sugar])
    # background mask excludes pad neurons
    np.testing.assert_array_equal(
        np.asarray(bg.mask).reshape(-1), np.asarray(d.inv_perm) >= 0)
