import os
import sys

import pytest

# tests run with the default single CPU device; only subprocess-based tests
# (test_distributed, test_dryrun_smoke) override XLA_FLAGS in their children.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis is an optional dev dependency (requirements-dev.txt): modules
# import these shims so their deterministic tests run everywhere and only
# the property-based tests skip when hypothesis is absent.
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def _noop_decorator(*args, **kwargs):
        return lambda f: f

    given = settings = _noop_decorator

    class _AnyStrategy:
        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

requires_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="hypothesis not installed")
