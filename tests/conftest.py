import os
import sys

# tests run with the default single CPU device; only subprocess-based tests
# (test_distributed, test_dryrun_smoke) override XLA_FLAGS in their children.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
