"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.neuron import LIFParams, LIFState, lif_step, lif_step_fx
from repro.core import synthetic_flywire
from repro.kernels.lif import lif_update, lif_update_fx
from repro.kernels.spike_prop import (build_blocked, spike_deliver,
                                      spike_deliver_dense_ref,
                                      spike_deliver_ref)
from repro.kernels.flash_attention import attention_ref, flash_attention


# ---------------------------------------------------------------- LIF ----

@pytest.mark.parametrize("n", [64, 128, 300, 1000])
@pytest.mark.parametrize("dt", [0.1, 1.0])
def test_lif_kernel_float_sweep(n, dt):
    p = LIFParams(dt=dt)
    rng = np.random.default_rng(n)
    st = LIFState(v=jnp.asarray(rng.normal(0, 3, n), jnp.float32),
                  g=jnp.asarray(abs(rng.normal(0, 1, n)), jnp.float32),
                  refrac=jnp.asarray(rng.integers(0, 3, n), jnp.int32))
    g_in = jnp.asarray(rng.normal(0, 2, n), jnp.float32)
    v_in = jnp.asarray(rng.normal(0, 5, n), jnp.float32)
    force = jnp.asarray(rng.random(n) < 0.05)
    st_k, spk_k = lif_update(st, g_in, p, v_in, force)
    st_r, spk_r = lif_step(st, g_in, p, v_in, force)
    np.testing.assert_allclose(st_k.v, st_r.v, atol=1e-6)
    np.testing.assert_allclose(st_k.g, st_r.g, atol=1e-6)
    np.testing.assert_array_equal(st_k.refrac, st_r.refrac)
    np.testing.assert_array_equal(spk_k, spk_r)


@pytest.mark.parametrize("n", [128, 500])
def test_lif_kernel_fixed_point_exact(n):
    """Fixed-point path must be bit-exact (integer arithmetic)."""
    p = LIFParams()
    rng = np.random.default_rng(n)
    st = LIFState(v=jnp.asarray(rng.integers(-10000, 10000, n), jnp.int32),
                  g=jnp.asarray(rng.integers(0, 5000, n), jnp.int32),
                  refrac=jnp.asarray(rng.integers(0, 3, n), jnp.int32))
    g_in = jnp.asarray(rng.integers(-50, 50, n), jnp.int32)
    v_in = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
    force = jnp.asarray(rng.random(n) < 0.05)
    st_k, spk_k = lif_update_fx(st, g_in, p, v_in, force)
    st_r, spk_r = lif_step_fx(st, g_in, p, v_in, force)
    np.testing.assert_array_equal(st_k.v, st_r.v)
    np.testing.assert_array_equal(st_k.g, st_r.g)
    np.testing.assert_array_equal(spk_k, spk_r)


def test_lif_kernel_multistep_trajectory():
    p = LIFParams()
    n = 256
    stk = str_ = LIFState(v=jnp.zeros(n), g=jnp.zeros(n),
                          refrac=jnp.zeros(n, jnp.int32))
    rng = np.random.default_rng(0)
    for _ in range(30):
        g_in = jnp.asarray(rng.integers(0, 30, n), jnp.float32) * 0.275
        stk, sk = lif_update(stk, g_in, p)
        str_, sr = lif_step(str_, g_in, p)
        np.testing.assert_allclose(stk.v, str_.v, atol=1e-4)
        np.testing.assert_array_equal(sk, sr)


# --------------------------------------------------------- spike_prop ----

@pytest.mark.parametrize("n,nnz,rate", [(256, 5_000, 0.01), (1000, 30_000, 0.05),
                                        (777, 10_000, 0.2), (1500, 20_000, 0.0)])
def test_spike_prop_sweep(n, nnz, rate):
    c = synthetic_flywire(n=n, target_synapses=nnz, seed=n)
    bs = build_blocked(c)
    rng = np.random.default_rng(1)
    spk = rng.random(n) < rate
    out = np.asarray(spike_deliver(bs, spk))
    np.testing.assert_allclose(out, np.asarray(spike_deliver_ref(bs, spk)),
                               atol=1e-3)
    np.testing.assert_allclose(
        out, np.asarray(spike_deliver_dense_ref(c, spk)), atol=1e-3)


def test_spike_prop_quantized_weights():
    from repro.core import quantize_weights
    c = synthetic_flywire(n=600, target_synapses=15_000, seed=9)
    wq = quantize_weights(c.in_weights, 9)
    bs = build_blocked(c, quantized=wq)
    spk = np.random.default_rng(2).random(c.n) < 0.1
    out = np.asarray(spike_deliver(bs, spk))
    ref = np.asarray(spike_deliver_dense_ref(c, spk, quantized=wq))
    np.testing.assert_allclose(out, ref, atol=1e-3)


def test_spike_prop_gating_zero_blocks():
    """All-silent input -> all tiles gated -> exact zeros."""
    c = synthetic_flywire(n=500, target_synapses=8_000, seed=10)
    bs = build_blocked(c)
    out = np.asarray(spike_deliver(bs, np.zeros(c.n, bool)))
    assert np.abs(out).max() == 0.0


def test_build_blocked_invariants():
    """Tile-store structural invariants the blocked engine relies on."""
    from repro.kernels.spike_prop.kernel import SRC_BLK, TGT_BLK
    c = synthetic_flywire(n=1000, target_synapses=30_000, seed=5)
    bs = build_blocked(c)
    assert bs.n_tb == -(-c.n // TGT_BLK)
    assert bs.n_sb == -(-c.n // SRC_BLK)
    assert bs.blk_id.shape[0] == bs.n_tb
    assert bs.weights.shape == (*bs.blk_id.shape, TGT_BLK, SRC_BLK)
    valid = bs.blk_id < bs.n_sb
    assert bs.tiles_stored == int(valid.sum())
    assert 0 < bs.tiles_stored <= bs.n_tb * bs.n_sb
    # occupancy is nnz over stored-tile capacity, in (0, 1]
    assert np.isclose(bs.occupancy,
                      c.nnz / (bs.tiles_stored * TGT_BLK * SRC_BLK))
    assert 0.0 < bs.occupancy <= 1.0
    # pad tiles carry no weight; stored mass equals the connectome's
    assert np.all(bs.weights[~valid] == 0.0)
    assert bs.weights.sum() == float(c.in_weights.sum())
    # within a target block, each source block appears in at most one tile
    for tb in range(bs.n_tb):
        ids = bs.blk_id[tb][valid[tb]]
        assert len(np.unique(ids)) == len(ids)


# ---------------------------------------------------- flash attention ----

@pytest.mark.parametrize("B,H,Hkv,Sq,D,causal,window", [
    (1, 2, 2, 256, 64, True, None),
    (2, 4, 2, 128, 64, True, None),      # GQA
    (1, 2, 1, 200, 32, True, None),      # padding (200 % 128 != 0)
    (1, 2, 2, 256, 64, False, None),     # bidirectional (whisper encoder)
    (1, 2, 2, 512, 64, True, 128),       # sliding window (gemma3 local)
    (1, 4, 4, 384, 128, True, 96),
])
def test_flash_attention_sweep(B, H, Hkv, Sq, D, causal, window):
    rng = np.random.default_rng(Sq + D)
    q = jnp.asarray(rng.normal(0, 1, (B, H, Sq, D)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (B, Hkv, Sq, D)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (B, Hkv, Sq, D)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(out, ref, atol=2e-4)


def test_flash_attention_matches_model_chunked_and_banded():
    """Kernel, chunked-jnp and banded-jnp paths are interchangeable."""
    from repro.models.layers import banded_attention, chunked_attention
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(0, 1, (2, 4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (2, 2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (2, 2, 256, 64)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, window=None)
    b = chunked_attention(q, k, v, causal=True, window=None, chunk=128)
    c = banded_attention(q, k, v, causal=True, window=None, block=64)
    np.testing.assert_allclose(a, b, atol=2e-4)
    np.testing.assert_allclose(b, c, atol=2e-4)
    # windowed variant
    a = flash_attention(q, k, v, causal=True, window=64)
    b = chunked_attention(q, k, v, causal=True, window=64, chunk=128)
    c = banded_attention(q, k, v, causal=True, window=64, block=64)
    np.testing.assert_allclose(a, b, atol=2e-4)
    np.testing.assert_allclose(b, c, atol=2e-4)


def test_windowed_scan_attention_matches_oracle():
    """The scan-form sliding-window attention (§Perf variant) is exact."""
    from repro.models.layers import chunked_attention, windowed_attention
    rng = np.random.default_rng(3)
    for (B, H, Hkv, S, D, W, blk) in [(1, 2, 1, 256, 32, 64, 64),
                                      (2, 4, 2, 512, 64, 128, 128),
                                      (1, 2, 2, 300, 32, 96, 128),
                                      (1, 2, 1, 512, 32, 700, 128)]:
        q = jnp.asarray(rng.normal(0, 1, (B, H, S, D)), jnp.float32)
        k = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
        v = jnp.asarray(rng.normal(0, 1, (B, Hkv, S, D)), jnp.float32)
        a = windowed_attention(q, k, v, window=W, block=blk)
        b = chunked_attention(q, k, v, causal=True, window=W, chunk=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-4)
