"""Per-architecture smoke tests (assignment requirement) + prefill/decode
consistency against the full forward pass."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_arch_names, get_config
from repro.configs.shapes import SHAPES, cell_supported, input_specs
from repro.models import (count_params, decode_step, forward, init_params,
                          loss_fn, prefill)

ARCHS = all_arch_names()


def make_batch(cfg, B=2, S=32, with_labels=True, seed=0):
    rng = np.random.default_rng(seed)
    if cfg.is_encdec:
        b = {"frames": jnp.asarray(rng.normal(0, 1, (B, cfg.enc_seq,
                                                     cfg.d_model)),
                                   jnp.float32),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab,
                                                (B, cfg.dec_max)), jnp.int32)}
    elif cfg.n_patches:
        b = {"patches": jnp.asarray(rng.normal(0, 1, (B, cfg.n_patches,
                                                      cfg.d_model)),
                                    jnp.float32),
             "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    else:
        b = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if with_labels:
        b["labels"] = jnp.asarray(
            rng.integers(0, cfg.vocab, b["tokens"].shape), jnp.int32)
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one grad step on the reduced config: output shapes
    correct, no NaNs (the per-arch smoke test required by the task)."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    logits, aux = forward(params, batch, cfg)
    S_expect = batch["tokens"].shape[1] + (cfg.n_patches or 0)
    assert logits.shape == (2, S_expect, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """decode_step after prefill(S-1 tokens) must reproduce forward's
    last-position logits — KV caches, recurrent states and token-shift
    states all have to be exactly right for this to hold."""
    cfg = get_config(arch, smoke=True)
    params = init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg, with_labels=False, seed=3)
    full_logits, _ = forward(params, batch, cfg)

    toks = batch["tokens"]
    S = toks.shape[1]
    pre_batch = dict(batch, tokens=toks[:, :S - 1])
    max_len = cfg.dec_max if cfg.is_encdec else S + 8
    _, cache = prefill(params, pre_batch, cfg, max_len)
    pos = (S - 1) + (cfg.n_patches or 0)
    logits, _ = decode_step(params, cache, toks[:, -1], jnp.int32(pos), cfg)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, -1]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count_matches_published(arch):
    """Full configs hit their published parameter counts (sanity that the
    config block was transcribed faithfully)."""
    expected_b = {
        "grok-1-314b": (290, 340), "llama4-scout-17b-a16e": (95, 120),
        "recurrentgemma-2b": (2.3, 3.5), "phi3-medium-14b": (13, 16),
        "qwen2.5-14b": (13, 16), "command-r-35b": (30, 38),
        "gemma3-12b": (10, 14), "whisper-medium": (0.6, 1.0),
        "rwkv6-7b": (6.5, 8.5), "llava-next-34b": (31, 37),
    }
    n = count_params(get_config(arch)) / 1e9
    lo, hi = expected_b[arch]
    assert lo <= n <= hi, f"{arch}: {n:.1f}B not in [{lo}, {hi}]"


@pytest.mark.parametrize("arch", ARCHS)
def test_cell_support_matrix(arch):
    """The 40-cell support matrix: every cell either supported or carrying
    a documented skip reason."""
    cfg = get_config(arch)
    for cell in SHAPES:
        ok, why = cell_supported(cfg, cell)
        assert ok or why
        if ok:
            specs = input_specs(cfg, cell)
            assert specs  # shape-buildable


def test_decode_with_vector_positions():
    """Continuous batching: per-slot positions."""
    cfg = get_config("qwen2.5-14b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, with_labels=False)
    S = batch["tokens"].shape[1]
    _, cache = prefill(params, batch, cfg, S + 8)
    pos = jnp.array([S, S - 2], jnp.int32)
    logits, cache2 = decode_step(params, cache, jnp.array([1, 2]), pos, cfg)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_moe_aux_loss_and_balance():
    cfg = get_config("grok-1-314b", smoke=True)
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    _, aux = forward(params, batch, cfg)
    # Switch aux loss is ~1.0 at perfect balance, >= 1 otherwise
    assert 0.5 < float(aux) / cfg.n_layers < 4.0


def test_rglru_assoc_scan_matches_sequential():
    """The log-depth associative-scan recurrence (the seq-shardable §Perf
    variant) is numerically identical to the sequential scan."""
    from repro.models.param import split_tree
    from repro.models.rglru import rglru_apply, rglru_init
    p, _ = split_tree(rglru_init(jax.random.PRNGKey(0), 32, 48))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 37, 32))
    o1, (h1, t1) = rglru_apply(p, x, assoc=False)
    o2, (h2, t2) = rglru_apply(p, x, assoc=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(t1), np.asarray(t2))
