"""Dry-run machinery on a small host mesh in a subprocess: every
architecture's reduced config lowers + compiles for each supported cell
kind on a (data=2, model=2) mesh — the multi-pod path is exercised with
(pod=2, data=2, model=2)."""

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, "src")
    import jax
    from repro.configs import all_arch_names, get_config
    from repro.configs.shapes import SHAPES, cell_supported
    from repro.launch.build import build_step, lower_and_compile
    from repro.launch.mesh import make_host_mesh

    multi = len(sys.argv) > 1 and sys.argv[1] == "multi"
    mesh = (make_host_mesh(data=2, model=2, pod=2) if multi
            else make_host_mesh(data=2, model=2))
    cells = sys.argv[2].split(",")
    for arch in all_arch_names():
        cfg = get_config(arch)
        for cell in cells:
            ok, why = cell_supported(cfg, cell)
            if not ok:
                print(f"SKIP {arch} {cell}: {why}")
                continue
            built = build_step(arch, cell, mesh, smoke=True, microbatches=2)
            lowered, compiled = lower_and_compile(built, mesh)
            cost = compiled.cost_analysis()
            cost = cost[0] if isinstance(cost, (list, tuple)) else cost
            assert cost.get("flops", 0) > 0 or built.kind == "decode"
            print(f"OK {arch} {cell}")
    print("ALL_OK")
""")


def _run(args):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    root = os.path.join(os.path.dirname(__file__), "..")
    script = os.path.join(root, ".pytest_dryrun_smoke.py")
    with open(script, "w") as f:
        f.write(SCRIPT)
    try:
        out = subprocess.run([sys.executable, script] + args,
                             capture_output=True, text=True, timeout=1800,
                             env=env, cwd=root)
    finally:
        os.remove(script)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "ALL_OK" in out.stdout
    return out.stdout


def test_all_archs_compile_single_pod_train_and_decode():
    out = _run(["single", "train_4k,decode_32k"])
    assert out.count("OK ") >= 20


def test_all_archs_compile_multi_pod_prefill_and_long():
    out = _run(["multi", "prefill_32k,long_500k"])
    assert out.count("OK ") >= 13
